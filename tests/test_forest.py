"""Random-Forest regression (from scratch)."""

import numpy as np
import pytest

from repro.core.forest import RandomForestRegressor, mape, rmspe


def test_fits_piecewise_constant():
    rng = np.random.default_rng(0)
    X = rng.integers(0, 8, size=(2000, 2)).astype(float)
    y = X[:, 0] * 10 + X[:, 1]
    f = RandomForestRegressor(n_estimators=16, max_depth=10, seed=0).fit(X, y)
    yp = f.predict(X)
    assert np.max(np.abs(yp - y)) < 1.0


def test_fits_product_with_feature():
    rng = np.random.default_rng(1)
    a = rng.uniform(1, 50, size=3000)
    b = rng.uniform(1, 50, size=3000)
    X = np.stack([a, b, a * b], axis=1)  # derived feature
    y = a * b
    f = RandomForestRegressor(n_estimators=16, max_depth=16, seed=0).fit(X, y)
    test = X[:200]
    assert mape(y[:200], f.predict(test)) < 5.0


def test_deterministic_given_seed():
    rng = np.random.default_rng(2)
    X = rng.normal(size=(300, 3))
    y = X @ np.array([1.0, 2.0, 3.0])
    f1 = RandomForestRegressor(n_estimators=8, seed=7).fit(X, y)
    f2 = RandomForestRegressor(n_estimators=8, seed=7).fit(X, y)
    assert np.array_equal(f1.predict(X), f2.predict(X))


def test_min_samples_leaf():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(100, 2))
    y = rng.normal(size=100)
    f = RandomForestRegressor(n_estimators=4, min_samples_leaf=10, seed=0).fit(X, y)
    f.predict(X)  # no crash; leaves >= 10 samples


def test_metrics():
    y = np.array([1.0, 2.0, 4.0])
    yp = np.array([1.1, 1.8, 4.0])
    assert abs(mape(y, yp) - np.mean([10, 10, 0])) < 1e-9
    assert rmspe(y, yp) >= mape(y, yp) - 1e-9
