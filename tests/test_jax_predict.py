"""Jitted predict path: backend selection, parity, and serving integration.

The contract under test (see src/repro/core/jax_predict.py):

* layer predictions and the four platforms' analytical measurement kernels
  are **bitwise** identical across backends;
* whole-network predictions are bitwise except when a log-target ``exp``
  runs inside the compiled call (rtol 1e-12 there);
* every jax entry point degrades to the numpy path (never an error) when jax
  is unavailable or the request needs scalar semantics;
* importing the library never imports jax (the numpy-only CI leg).
"""

import subprocess
import sys

import numpy as np
import pytest

import repro.runtime.testing  # noqa: F401  (registers "stepped_sim")
from repro.api import Campaign, CampaignSpec, PerfOracle
from repro.core import jax_predict
from repro.core.batch import BlockBatch, ConfigBatch
from repro.core.blocks import Block
from repro.core.forest import RandomForestRegressor
from repro.registry import get_platform

FAST_FOREST = {"n_estimators": 8, "max_depth": 10}

needs_jax = pytest.mark.skipif(
    not jax_predict.jax_available(), reason="jax not importable in this env"
)


def _oracle(platform, layer_types, **platform_kwargs) -> PerfOracle:
    spec = CampaignSpec(
        platform=platform,
        layer_types=layer_types,
        n_samples=64,
        seed=0,
        forest_kwargs=FAST_FOREST,
        platform_kwargs=platform_kwargs or None,
    )
    return Campaign(spec).run()


@pytest.fixture(scope="module")
def toy_oracle() -> PerfOracle:
    return _oracle("stepped_sim", ("toy",))


@pytest.fixture(scope="module")
def tpu_oracle() -> PerfOracle:
    return _oracle("tpu_v5e", ("dense", "attention_decode", "embed"))


def _sample_batch(space, n, seed=0) -> ConfigBatch:
    rng = np.random.default_rng(seed)
    cols = {p: rng.integers(lo, hi + 1, size=n) for p, (lo, hi) in space.ranges.items()}
    for p, v in getattr(space, "fixed", {}).items():
        cols[p] = np.full(n, v)
    return ConfigBatch.from_columns(cols)


# ---------------------------------------------------------------- selection
def test_bucket_rows():
    assert jax_predict.bucket_rows(0) == 64
    assert jax_predict.bucket_rows(1) == 64
    assert jax_predict.bucket_rows(64) == 64
    assert jax_predict.bucket_rows(65) == 128
    assert jax_predict.bucket_rows(333) == 512
    assert jax_predict.bucket_rows(4096) == 4096


def test_resolve_backend_env(monkeypatch):
    monkeypatch.delenv(jax_predict._ENV_VAR, raising=False)
    assert jax_predict.resolve_backend() == "numpy"
    assert jax_predict.resolve_backend("numpy") == "numpy"
    monkeypatch.setenv(jax_predict._ENV_VAR, "numpy")
    assert jax_predict.resolve_backend() == "numpy"
    with pytest.raises(ValueError, match="unknown predict backend"):
        jax_predict.resolve_backend("tensorflow")
    monkeypatch.setenv(jax_predict._ENV_VAR, "tensorflow")
    with pytest.raises(ValueError, match="unknown predict backend"):
        jax_predict.resolve_backend()


@needs_jax
def test_resolve_backend_jax_and_auto(monkeypatch):
    assert jax_predict.resolve_backend("jax") == "jax"
    assert jax_predict.resolve_backend("auto") == "jax"
    monkeypatch.setenv(jax_predict._ENV_VAR, "jax")
    assert jax_predict.resolve_backend() == "jax"


def test_fallback_when_jax_unavailable(monkeypatch, toy_oracle):
    """With jax unimportable, backend 'jax' warns once and serves numpy."""
    monkeypatch.setattr(jax_predict, "_modules_cache", None)
    monkeypatch.setattr(jax_predict, "_import_failed", True)
    monkeypatch.setattr(jax_predict, "_warned_fallback", False)
    assert not jax_predict.jax_available()
    with pytest.warns(RuntimeWarning, match="falling back to numpy"):
        assert jax_predict.resolve_backend("jax") == "numpy"
    # warned exactly once
    assert jax_predict.resolve_backend("jax") == "numpy"
    # auto is a silent numpy fallback
    assert jax_predict.resolve_backend("auto") == "numpy"

    cfgs = [{"a": i % 40 + 1, "b": i % 20 + 1} for i in range(17)]
    y_np = toy_oracle.predict("toy", cfgs)
    assert np.array_equal(y_np, toy_oracle.predict("toy", cfgs, backend="jax"))
    nets = [[Block(kind="k", layers=(("toy", {"a": 4, "b": 2}),))]]
    assert np.array_equal(
        toy_oracle.predict_networks(nets),
        toy_oracle.predict_networks(nets, backend="jax"),
    )


def test_no_eager_jax_import():
    """The numpy-only leg: importing the library must not import jax."""
    code = (
        "import sys\n"
        "import repro.api, repro.serving\n"
        "import repro.obs, repro.obs.report, repro.obs.metrics, repro.obs.trace\n"
        "import repro.core.jax_predict, repro.core.steps, repro.core.sweeps\n"
        "import repro.accelerators.jax_kernels\n"
        "import repro.accelerators.tpu_v5e, repro.accelerators.ultratrail\n"
        "import repro.accelerators.vta, repro.accelerators.xla_cpu\n"
        "import repro.analysis\n"
        "assert 'jax' not in sys.modules, 'jax imported eagerly'\n"
    )
    subprocess.run([sys.executable, "-c", code], check=True)


# ------------------------------------------------------------ forest parity
@needs_jax
@pytest.mark.parametrize("n", [0, 1, 5, 64, 333])
def test_forest_predict_bitwise(n):
    rng = np.random.default_rng(3)
    Xtr = rng.uniform(0, 100, size=(200, 4))
    ytr = Xtr @ np.array([1e-6, 2e-6, 5e-7, 1e-7]) + rng.normal(0, 1e-8, 200)
    forest = RandomForestRegressor(n_estimators=10, max_depth=8, seed=0)
    forest.fit(Xtr, ytr)
    X = rng.uniform(-10, 120, size=(n, 4))
    assert np.array_equal(forest.predict(X), forest.predict(X, backend="jax"))


@needs_jax
def test_forest_engine_invalidated_on_refit():
    rng = np.random.default_rng(4)
    X = rng.uniform(0, 10, size=(100, 2))
    forest = RandomForestRegressor(n_estimators=5, max_depth=6, seed=0)
    forest.fit(X, X.sum(axis=1))
    y1 = forest.predict(X, backend="jax")
    forest.fit(X, X.prod(axis=1))  # refit resets the stack and its engine
    y2 = forest.predict(X, backend="jax")
    assert np.array_equal(y2, forest.predict(X))
    assert not np.array_equal(y1, y2)


@needs_jax
def test_layer_predict_bitwise_including_ragged(toy_oracle):
    cfgs = [{"a": (i * 7) % 64 + 1, "b": (i * 3) % 32 + 1} for i in range(333)]
    assert np.array_equal(
        toy_oracle.predict("toy", cfgs),
        toy_oracle.predict("toy", cfgs, backend="jax"),
    )
    # ragged key sets (an extra key on one row) take the row fallback on both
    ragged = [{"a": 5, "b": 3}, {"a": 9, "b": 2, "extra": 7}]
    assert np.array_equal(
        toy_oracle.predict("toy", ragged),
        toy_oracle.predict("toy", ragged, backend="jax"),
    )
    assert toy_oracle.predict("toy", [], backend="jax").shape == (0,)


# --------------------------------------------------- measurement kernel parity
PLATFORMS = [
    ("tpu_v5e", {}),
    ("ultratrail", {}),
    ("vta", {}),
    ("xla_cpu", {"synthetic": True, "repeats": 1}),
]


@needs_jax
@pytest.mark.parametrize("name,kwargs", PLATFORMS)
def test_measure_batch_bitwise(name, kwargs):
    plat = get_platform(name, **kwargs)
    for lt in plat.layer_types():
        for n in (1, 64, 257):
            batch = _sample_batch(plat.param_space(lt), n, seed=n)
            y_np = plat.measure_batch(lt, batch)
            plat.predict_backend = "jax"
            y_jx = plat.measure_batch(lt, batch)
            plat.predict_backend = None
            assert np.array_equal(y_np, y_jx), f"{name}/{lt} n={n}"


@needs_jax
def test_noisy_tpu_stays_numpy():
    """Per-config hash-seeded noise is scalar semantics; jax must not engage."""
    from repro.accelerators import jax_kernels

    plat = get_platform("tpu_v5e", noise=0.01)
    plat.predict_backend = "jax"
    batch = _sample_batch(plat.param_space("dense"), 16)
    assert jax_kernels.tpu_measure_batch(plat, "dense", batch) is None
    ref = get_platform("tpu_v5e", noise=0.01).measure_batch("dense", batch)
    assert np.array_equal(plat.measure_batch("dense", batch), ref)


@needs_jax
def test_wallclock_xla_cpu_stays_numpy():
    from repro.accelerators import jax_kernels

    plat = get_platform("xla_cpu", synthetic=False)
    plat.predict_backend = "jax"
    batch = _sample_batch(plat.param_space("dense"), 4)
    assert jax_kernels.xla_cpu_measure_batch(plat, "dense", batch) is None


# ------------------------------------------------------------ network parity
def _toy_nets():
    return [
        [
            Block(kind="k", layers=(("toy", {"a": 4, "b": 2}), ("toy", {"a": 8, "b": 4})), repeat=3),
            Block(kind="k", layers=(("toy", {"a": 16, "b": 8}),), collective_bytes=128.0),
        ],
        [Block(kind="k", layers=(("toy", {"a": 32, "b": 16}),))],
        [],
    ]


@needs_jax
def test_predict_networks_tolerance_log_target(toy_oracle):
    """log-target exp runs inside the compiled call: rtol 1e-12 applies."""
    assert all(e.log_target for e in toy_oracle.estimators.values())
    p_np = toy_oracle.predict_networks(_toy_nets())
    p_jx = toy_oracle.predict_networks(_toy_nets(), backend="jax")
    np.testing.assert_allclose(p_jx, p_np, rtol=1e-12, atol=0.0)


@needs_jax
def test_predict_networks_bitwise_without_log_target(toy_oracle):
    import dataclasses

    ests = {
        lt: dataclasses.replace(e, log_target=False)
        for lt, e in toy_oracle.estimators.items()
    }
    oracle = dataclasses.replace(toy_oracle, estimators=ests)
    p_np = oracle.predict_networks(_toy_nets())
    p_jx = oracle.predict_networks(_toy_nets(), backend="jax")
    assert np.array_equal(p_np, p_jx)


@needs_jax
def test_predict_networks_platform_oracles(tpu_oracle):
    nets = [
        [
            Block(kind="embed", layers=(("embed", {"tokens": 512, "vocab": 32000, "d_model": 1024}),), repeat=2),
            Block(
                kind="attn",
                layers=(
                    ("dense", {"tokens": 512, "d_in": 1024, "d_out": 3072}),
                    ("attention_decode", {"B": 8, "S_kv": 2048, "H": 16, "Dh": 128, "kv_ratio": 1}),
                ),
                collective_bytes=64.0,
            ),
        ],
        [Block(kind="mlp", layers=(("dense", {"tokens": 512, "d_in": 1024, "d_out": 4096}),))],
    ]
    p_np = tpu_oracle.predict_networks(nets)
    p_jx = tpu_oracle.predict_networks(nets, backend="jax")
    np.testing.assert_allclose(p_jx, p_np, rtol=1e-12, atol=0.0)


@needs_jax
def test_predict_network_batch_jax_matches_columnar(toy_oracle):
    nets = _toy_nets()
    flat = [b for net in nets for b in net]
    batch = BlockBatch.from_blocks(flat)
    net_id = np.repeat(np.arange(len(nets)), [len(n) for n in nets])
    y = jax_predict.predict_network_batch_jax(toy_oracle, batch, net_id, len(nets))
    assert y is not None
    np.testing.assert_allclose(
        y, toy_oracle.predict_networks(nets), rtol=1e-12, atol=0.0
    )


def test_predict_network_batch_falls_back_for_stub_estimators():
    class Stub:
        def predict(self, configs):
            return np.full(len(configs), 2.5e-6)

    oracle = PerfOracle(estimators={"toy": Stub()})
    nets = [[Block(kind="k", layers=(("toy", {"a": 4, "b": 2}),))]]
    # jax route declines stubs on both backends -> identical numpy answers
    assert np.array_equal(
        oracle.predict_networks(nets), oracle.predict_networks(nets, backend="jax")
    )


def test_empty_overlap_block_raises(toy_oracle):
    import dataclasses

    oracle = dataclasses.replace(toy_oracle, overlap_kinds=frozenset({"k"}))
    nets = [[Block(kind="k", layers=())]]
    with pytest.raises(ValueError, match="overlap block with zero layers"):
        oracle.predict_networks(nets)
    with pytest.raises(ValueError, match="overlap block with zero layers"):
        oracle.predict_networks(nets, backend="jax")


# ----------------------------------------------------------------- autotune
@needs_jax
def test_autotune_parity_across_backends_and_paths(tpu_oracle):
    import dataclasses as dc

    from repro.configs import get_config
    from repro.core.advisor import autotune
    from repro.models.config import InputShape

    cfg = get_config("qwen2-1.5b")
    shape = InputShape(name="t", seq_len=1024, global_batch=8, kind="decode")

    ranked = autotune(tpu_oracle, cfg, shape, chips=16)

    class ManyOnly:
        """Forces the predict_networks fallback path."""

        def __init__(self, oracle):
            self._o = oracle

        def predict_networks(self, networks):
            return self._o.predict_networks(networks)

    class OneOnly:
        """Forces the per-candidate predict_network loop."""

        def __init__(self, oracle):
            self._o = oracle

        def predict_network(self, blocks):
            return float(self._o.predict_networks([blocks])[0])

    for shim in (ManyOnly(tpu_oracle), OneOnly(tpu_oracle)):
        alt = autotune(shim, cfg, shape, chips=16)
        assert [c for c, _ in alt] == [c for c, _ in ranked]
        np.testing.assert_allclose(
            [s for _, s in alt], [s for _, s in ranked], rtol=0, atol=0
        )

    jax_oracle = dc.replace(tpu_oracle, predict_backend="jax")
    ranked_jx = autotune(jax_oracle, cfg, shape, chips=16)
    assert [c for c, _ in ranked_jx] == [c for c, _ in ranked]
    np.testing.assert_allclose(
        [s for _, s in ranked_jx], [s for _, s in ranked], rtol=1e-12
    )


# ---------------------------------------------------------- decompose_batch
def test_decompose_batch_matches_from_blocks():
    from repro.configs import ARCHS, get_config
    from repro.core.network import decompose, decompose_batch
    from repro.models.config import SHAPES, shape_applicable

    checked = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if not shape_applicable(cfg, shape):
                continue
            for dp, tp in ((1, 1), (4, 2), (16, 16)):
                ref = BlockBatch.from_blocks(decompose(cfg, shape, dp, tp))
                got = decompose_batch(cfg, shape, dp, tp)
                assert ref.kinds == got.kinds
                assert np.array_equal(ref.collective_bytes, got.collective_bytes)
                assert np.array_equal(ref.repeat, got.repeat)
                assert np.array_equal(ref.block_id, got.block_id)
                assert np.array_equal(ref.group_of, got.group_of)
                assert np.array_equal(ref.row_of, got.row_of)
                assert ref.group_types == got.group_types
                for a, b in zip(ref.group_configs, got.group_configs):
                    assert a.params == b.params
                    assert np.array_equal(a.values, b.values)
                checked += 1
    assert checked >= 50


# --------------------------------------------------- batched steps and sweeps
def test_determine_step_widths_matches_scalar():
    from repro.core.steps import determine_step_widths, find_step_width

    rng = np.random.default_rng(11)
    for trial in range(40):
        sweeps = {}
        for j in range(int(rng.integers(1, 6))):
            n = int(rng.choice([8, 24, 48, 96, 97]))
            x = np.arange(1, n + 1, dtype=float)
            kind = rng.integers(0, 4)
            if kind == 0:
                y = 3e-6 * x + 1e-6
            elif kind == 1:
                y = 2e-6 * np.ceil(x / int(rng.integers(2, 9)))
            elif kind == 2:
                y = 2e-6 * np.ceil(x / int(rng.integers(2, 9))) + rng.normal(0, 5e-9, n)
            else:
                y = np.full(n, 4e-6)
            sweeps[f"p{j}"] = (x, y)
        batched = determine_step_widths(sweeps)
        scalar = {p: find_step_width(x, y) for p, (x, y) in sweeps.items()}
        assert batched == scalar
        assert list(batched) == list(sweeps)  # original param order


def test_run_sweeps_grouped_matches_per_window():
    from repro.core.sweeps import run_sweeps, sweep_window

    plat = get_platform("ultratrail")
    out = run_sweeps(plat, "conv1d", n_points=96)
    space = plat.param_space("conv1d")
    defaults = plat.defaults("conv1d")
    anchor = space.with_fixed(defaults)
    assert list(out) == list(space.params)
    for p in space.params:
        lo, hi = space.ranges[p]
        xs = sweep_window(lo, hi, defaults.get(p, lo), 96)
        base_cfg = dict(anchor)
        base_cfg.setdefault(p, int(xs[0]))
        batch = ConfigBatch.from_anchor(base_cfg, len(xs)).replace(p, xs)
        ys = plat.measure_batch("conv1d", batch)
        got_x, got_y = out[p]
        assert np.array_equal(got_x, xs)
        assert np.array_equal(got_y, ys)


# ----------------------------------------------------------------- serving
@needs_jax
def test_served_equals_direct_with_jax_backend(toy_oracle):
    from repro.serving import OracleServer, ServeSpec

    cfgs = [{"a": (i * 5) % 64 + 1, "b": (i * 7) % 32 + 1} for i in range(50)]
    nets = _toy_nets()[:2]
    spec = ServeSpec(window_s=0.001, predict_backend="jax")
    with OracleServer(oracles={"stepped_sim": toy_oracle}, spec=spec) as srv:
        r = srv.handle(
            {"op": "predict", "platform": "stepped_sim", "layer_type": "toy", "configs": cfgs}
        )
        assert r["ok"], r
        direct = toy_oracle.predict("toy", cfgs, backend="jax")
        assert np.array_equal(np.asarray(r["result"]), direct)
        # repeat: answered from cache, still the same bits
        r2 = srv.handle(
            {"op": "predict", "platform": "stepped_sim", "layer_type": "toy", "configs": cfgs}
        )
        assert np.array_equal(np.asarray(r2["result"]), direct)

        rn = srv.handle(
            {
                "op": "predict_networks",
                "platform": "stepped_sim",
                "networks": [[_payload(b) for b in net] for net in nets],
            }
        )
        assert rn["ok"], rn
        direct_n = toy_oracle.predict_networks(nets, backend="jax")
        assert np.array_equal(np.asarray(rn["result"]), direct_n)
    # the injected oracle object was never mutated
    assert toy_oracle.predict_backend is None


def _payload(block: Block) -> dict:
    return {
        "kind": block.kind,
        "layers": [[lt, dict(cfg)] for lt, cfg in block.layers],
        "collective_bytes": block.collective_bytes,
        "repeat": block.repeat,
    }


@needs_jax
def test_network_cache_keys_are_backend_scoped(toy_oracle):
    """A numpy-warmed network cache entry must not serve a jax-backend oracle
    (answers can differ by an ulp via the compiled log-target exp); layer
    entries stay shared because layer parity is bitwise."""
    import dataclasses as dc

    from repro.serving import OracleServer, ServeSpec

    assert any(e.log_target for e in toy_oracle.estimators.values())
    srv = OracleServer(oracles={"stepped_sim": toy_oracle}, spec=ServeSpec())
    assert srv._network_key_scope(toy_oracle) == ()
    assert srv._network_key_scope(dc.replace(toy_oracle, predict_backend="jax")) == ("jax",)
    # bitwise network parity (no log target) -> key sharing is allowed
    ests = {lt: dc.replace(e, log_target=False) for lt, e in toy_oracle.estimators.items()}
    linear = dc.replace(toy_oracle, estimators=ests, predict_backend="jax")
    assert srv._network_key_scope(linear) == ()
    srv.close()

    nets = _toy_nets()[:1]
    poison = 123.456
    spec = ServeSpec(window_s=0.001, predict_backend="jax")
    with OracleServer(oracles={"stepped_sim": toy_oracle}, spec=spec) as srv:
        oracle = srv._oracle("stepped_sim")
        numpy_keys = [("stepped_sim",) + k for k in oracle.network_keys(nets)]
        srv.cache.put_many(numpy_keys, [poison])  # what a numpy server would warm
        r = srv.handle(
            {
                "op": "predict_networks",
                "platform": "stepped_sim",
                "networks": [[_payload(b) for b in nets[0]]],
            }
        )
        assert r["ok"], r
        assert r["result"][0] != poison  # scoped key -> recomputed, not served
        np.testing.assert_allclose(
            r["result"], toy_oracle.predict_networks(nets, backend="jax"), rtol=1e-12
        )


def test_network_cache_keys_unscoped_on_numpy_backend(toy_oracle):
    from repro.serving import OracleServer, ServeSpec

    nets = _toy_nets()[:1]
    poison = 123.456
    with OracleServer(oracles={"stepped_sim": toy_oracle}, spec=ServeSpec(window_s=0.001)) as srv:
        keys = [("stepped_sim",) + k for k in toy_oracle.network_keys(nets)]
        srv.cache.put_many(keys, [poison])
        r = srv.handle(
            {
                "op": "predict_networks",
                "platform": "stepped_sim",
                "networks": [[_payload(b) for b in nets[0]]],
            }
        )
        assert r["ok"], r
        assert r["result"][0] == poison  # same backend -> cache hit by design
