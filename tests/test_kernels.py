"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per-kernel shape/dtype sweeps with assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import chunked_attention, full_attention
from repro.models.ssm import ssd_chunked


KEY = jax.random.PRNGKey(42)


def _tol(dt):
    return dict(atol=2e-2, rtol=2e-2) if dt == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,sq,h,kvh,d,dt",
        [
            (1, 128, 4, 4, 64, jnp.float32),   # MHA
            (2, 256, 8, 2, 80, jnp.bfloat16),  # GQA, zamba2-like head_dim
            (1, 200, 6, 1, 128, jnp.float32),  # MQA, ragged seq (padding path)
            (1, 384, 12, 2, 96, jnp.float32),  # qwen2-like
        ],
    )
    def test_against_oracle(self, b, sq, h, kvh, d, dt):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, sq, h, d), dt)
        k = jax.random.normal(ks[1], (b, sq, kvh, d), dt)
        v = jax.random.normal(ks[2], (b, sq, kvh, d), dt)
        o = ops.flash_attention(q, k, v, causal=True)
        o_ref = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(o_ref, np.float32), **_tol(dt)
        )

    def test_non_causal(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 128, 4, 64))
        k = jax.random.normal(ks[1], (1, 256, 4, 64))
        v = jax.random.normal(ks[2], (1, 256, 4, 64))
        o = ops.flash_attention(q, k, v, causal=False)
        o_ref = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 256)])
    def test_block_shape_sweep(self, block_q, block_k):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 256, 4, 64))
        k = jax.random.normal(ks[1], (1, 256, 2, 64))
        v = jax.random.normal(ks[2], (1, 256, 2, 64))
        o = ops.flash_attention(q, k, v, causal=True, block_q=block_q, block_k=block_k)
        o_ref = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5, rtol=2e-5)


class TestSSDScan:
    @pytest.mark.parametrize(
        "b,s,h,p,n,dt",
        [
            (2, 256, 4, 64, 64, jnp.float32),
            (1, 300, 8, 64, 128, jnp.bfloat16),  # mamba2-780m-like, ragged seq
            (1, 128, 2, 32, 16, jnp.float32),
        ],
    )
    def test_against_oracle(self, b, s, h, p, n, dt):
        ks = jax.random.split(KEY, 4)
        xb = jax.random.normal(ks[0], (b, s, h, p), dt) * 0.2
        la = -jnp.abs(jax.random.normal(ks[1], (b, s, h), jnp.float32)) * 0.1
        bm = jax.random.normal(ks[2], (b, s, n), dt) * 0.3
        cm = jax.random.normal(ks[3], (b, s, n), dt) * 0.3
        y = ops.ssd_scan(xb, la, bm, cm)
        y_ref, _ = ref.ssd_ref(xb, la, bm, cm)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
            atol=2e-2 if dt == jnp.bfloat16 else 2e-5, rtol=5e-2 if dt == jnp.bfloat16 else 2e-5,
        )

    def test_xla_twin_matches_oracle(self):
        """models.ssm.ssd_chunked (the XLA path) == naive recurrence."""
        ks = jax.random.split(KEY, 4)
        b, s, h, p, n = 2, 200, 4, 8, 16
        xb = jax.random.normal(ks[0], (b, s, h, p)) * 0.2
        la = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.1
        bm = jax.random.normal(ks[2], (b, s, n)) * 0.3
        cm = jax.random.normal(ks[3], (b, s, n)) * 0.3
        y_ref, st_ref = ref.ssd_ref(xb, la, bm, cm)
        for chunk in (16, 64, 128):
            y, st = ssd_chunked(xb, la, bm, cm, chunk)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5, rtol=2e-4)
            np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), atol=2e-5, rtol=2e-4)


class TestChunkedAttentionTwin:
    def test_chunked_matches_full(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, 96, 8, 32))
        k = jax.random.normal(ks[1], (2, 96, 2, 32))
        v = jax.random.normal(ks[2], (2, 96, 2, 32))
        o_full = full_attention(q, k, v, causal=True)
        for bk in (17, 32, 128):
            o = chunked_attention(q, k, v, causal=True, block_k=bk)
            np.testing.assert_allclose(np.asarray(o), np.asarray(o_full), atol=2e-5, rtol=2e-4)

    def test_decode_offset(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, 1, 8, 32))
        k = jax.random.normal(ks[1], (2, 64, 2, 32))
        v = jax.random.normal(ks[2], (2, 64, 2, 32))
        o1 = full_attention(q, k, v, causal=True, q_offset=63)
        o2 = chunked_attention(q, k, v, causal=True, q_offset=63, block_k=16)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5, rtol=2e-4)
