"""Measurement runtime: sharded scheduler, worker pool, crash-safe journal.

The three guarantees under test:

* **determinism** — a campaign produces bitwise-identical results (estimator
  checkpoints, predictions, cache stats) for any worker count, because chunk
  boundaries depend only on ``chunk_size`` and results merge in
  first-occurrence order;
* **crash-safe resume** — killing a run mid-campaign loses at most the
  chunks still in flight (completed chunks are journaled the moment they
  finish, even out of merge order); re-running replays the fsync'd journal
  into the measurement cache and finishes with zero duplicate measurements,
  bitwise-equal to an uninterrupted run;
* **fault tolerance** — transient chunk failures and gather timeouts are
  retried with backoff; corrupt journal lines are skipped with a warning.
"""

from __future__ import annotations

import json
import os
from concurrent.futures import Future

import numpy as np
import pytest

import repro.runtime.testing  # noqa: F401  (registers "stepped_sim")
from repro.api import Campaign, CampaignSpec, MeasurementCache, RuntimeSpec
from repro.core.batch import ConfigBatch
from repro.runtime import (
    JournalCorruptionWarning,
    MeasurementError,
    MeasurementJournal,
    MeasurementRuntime,
    MeasurementScheduler,
    SerialExecutor,
    WorkerPool,
)
from repro.runtime.testing import SteppedSimPlatform

FAST_FOREST = {"n_estimators": 4, "max_depth": 10}


def _spec(**kwargs) -> CampaignSpec:
    base = dict(
        platform="stepped_sim",
        layer_types=("toy",),
        n_samples=48,
        seed=0,
        forest_kwargs=FAST_FOREST,
    )
    base.update(kwargs)
    return CampaignSpec(**base)


def _hub_content(hub_dir) -> dict:
    """Exact persisted content of a hub, byte-compared array by array.

    Two normalizations, both about *when* a checkpoint was written rather than
    *what* was measured: ``npz`` zip-member timestamps are bypassed by reading
    the stored arrays, and the meta blob's ``mean_measure_seconds`` (wall-clock
    bookkeeping for Table-1 reporting) is dropped.  Everything derived from
    measurements — tree node tables, step widths, spaces, targets — must match
    to the byte.  Manifests are skipped: they are derived from the arrays.
    """
    content: dict = {}
    for root, _, files in os.walk(hub_dir):
        for fname in sorted(files):
            path = os.path.join(root, fname)
            rel = os.path.relpath(path, hub_dir)
            if fname.endswith(".npz"):
                entry: dict = {}
                with np.load(path) as z:
                    for k in z.files:
                        if k == "meta":
                            meta = json.loads(bytes(z[k]).decode("utf-8"))
                            meta.pop("mean_measure_seconds", None)
                            entry[k] = json.dumps(meta, sort_keys=True)
                        else:
                            entry[k] = (z[k].dtype.str, z[k].shape, z[k].tobytes())
                content[rel] = entry
            elif fname == "oracle.json":
                with open(path, "rb") as f:
                    content[rel] = f.read()
    return content


QUERIES = [{"a": 3, "b": 31}, {"a": 10, "b": 5}, {"a": 33, "b": 17}, {"a": 64, "b": 1}]


# ---------------------------------------------------------------- determinism
class TestWorkerCountDeterminism:
    def test_bitwise_identical_for_worker_counts(self, tmp_path):
        """Same seed => bitwise-identical campaigns for workers in {1, 2, 4}."""
        contents, preds, stats = [], [], []
        for workers in (1, 2, 4):
            hub = tmp_path / f"hub_w{workers}"
            campaign = Campaign(_spec(hub_dir=str(hub)))
            oracle = campaign.run(
                runtime=RuntimeSpec(workers=workers, chunk_size=16, journal_path=None)
            )
            contents.append(_hub_content(hub))
            preds.append(oracle.predict("toy", QUERIES))
            cache_stats = campaign.stats()
            del cache_stats["measure_seconds"]  # wall clock, not deterministic
            stats.append(cache_stats)
        assert contents[0] == contents[1] == contents[2]
        assert np.array_equal(preds[0], preds[1])
        assert np.array_equal(preds[0], preds[2])
        assert stats[0] == stats[1] == stats[2]

    def test_scheduler_merges_in_first_occurrence_order(self):
        platform = SteppedSimPlatform()
        space_batch = ConfigBatch.from_columns(
            {"a": np.arange(1, 61), "b": (np.arange(1, 61) % 32) + 1}
        )
        direct = platform.measure_batch("toy", space_batch)
        for chunk_size in (1, 7, 64, 1000):
            scheduler = MeasurementScheduler(
                SerialExecutor(platform), chunk_size=chunk_size
            )
            merged = scheduler.measure_batch("stepped_sim", "toy", space_batch)
            assert np.array_equal(merged, direct)

    def test_empty_batch(self):
        scheduler = MeasurementScheduler(SerialExecutor(SteppedSimPlatform()))
        out = scheduler.measure_batch("stepped_sim", "toy", ConfigBatch.from_dicts([]))
        assert out.shape == (0,)


# ------------------------------------------------------- xla_cpu (acceptance)
class TestXLACPUSyntheticCampaign:
    """The ISSUE acceptance path: xla_cpu + process pool + journal resume."""

    def _spec(self, hub_dir=None):
        return CampaignSpec(
            platform="xla_cpu",
            layer_types=("dense",),
            n_samples=32,
            seed=0,
            forest_kwargs=FAST_FOREST,
            platform_kwargs={"synthetic": True, "repeats": 1},
            hub_dir=hub_dir,
        )

    def test_pool_checkpoints_byte_identical_to_serial(self, tmp_path):
        hub_serial, hub_pool = tmp_path / "serial", tmp_path / "pool"
        c_serial = Campaign(self._spec(str(hub_serial)))
        c_serial.run(runtime=RuntimeSpec(workers=1, chunk_size=64, journal_path=None))
        c_pool = Campaign(self._spec(str(hub_pool)))
        c_pool.run(
            runtime=RuntimeSpec(
                workers=2,
                chunk_size=64,
                journal_path=str(tmp_path / "pool.jsonl"),
            )
        )
        assert _hub_content(hub_serial) == _hub_content(hub_pool)
        assert c_serial.cache.misses == c_pool.cache.misses

        # Resume from the pool's journal: a fresh campaign re-measures nothing.
        resumed = Campaign(self._spec())
        oracle = resumed.run(
            runtime=RuntimeSpec(workers=1, journal_path=str(tmp_path / "pool.jsonl"))
        )
        assert resumed.cache.misses == 0
        assert resumed.cache.replayed == c_pool.cache.misses
        assert resumed.last_run_stats["measured"] == 0
        ref = Campaign(self._spec()).run()
        test = [{"tokens": 17, "d_in": 100, "d_out": 640}]
        assert np.array_equal(oracle.predict("dense", test), ref.predict("dense", test))


# ------------------------------------------------------------- journal resume
class _CrashingPlatform(SteppedSimPlatform):
    """Raises once a measurement budget is exhausted (simulated mid-run kill)."""

    def __init__(self, fail_after_rows: int) -> None:
        super().__init__()
        self._remaining = fail_after_rows

    def measure_batch(self, layer_type, batch):
        if self._remaining < len(batch):
            raise RuntimeError("injected crash")
        self._remaining -= len(batch)
        return super().measure_batch(layer_type, batch)


class TestJournalResume:
    def test_serial_journals_each_chunk_as_it_completes(self, tmp_path):
        """Serial execution must journal chunk-by-chunk, not batch-at-the-end.

        A crash mid-batch may lose only the chunk in flight — every chunk
        measured before it must already be on disk.
        """
        path = str(tmp_path / "j.jsonl")
        platform = _CrashingPlatform(fail_after_rows=20)
        journal = MeasurementJournal(path)
        scheduler = MeasurementScheduler(
            SerialExecutor(platform), journal=journal, chunk_size=8, max_retries=0
        )
        batch = ConfigBatch.from_columns({"a": np.arange(1, 33), "b": np.arange(1, 33)})
        with pytest.raises(MeasurementError):
            scheduler.measure_batch("stepped_sim", "toy", batch)
        journal.close()
        rows = sum(len(r["rows"]) for r in MeasurementJournal(path).iter_records())
        assert rows == 16  # two full chunks durably recorded before the crash

    def test_prefetched_chunks_journal_even_when_an_earlier_chunk_fails(self, tmp_path):
        """Pool path: completed chunks persist regardless of merge order.

        Chunk 0 dies permanently while chunks 1..3 complete in other workers;
        their measurements must be on disk when the run aborts.
        """

        class _FirstChunkDies(SerialExecutor):
            workers = 2  # prefetch path

            def __init__(self, platform):
                super().__init__(platform)
                self.calls = 0

            def submit(self, layer_type, batch):
                self.calls += 1
                if self.calls == 1:
                    future: Future = Future()
                    future.set_exception(RuntimeError("worker died"))
                    return future
                return super().submit(layer_type, batch)

        path = str(tmp_path / "j.jsonl")
        journal = MeasurementJournal(path)
        scheduler = MeasurementScheduler(
            _FirstChunkDies(SteppedSimPlatform()),
            journal=journal,
            chunk_size=8,
            max_retries=0,
        )
        batch = ConfigBatch.from_columns({"a": np.arange(1, 33), "b": np.arange(1, 33)})
        with pytest.raises(MeasurementError):
            scheduler.measure_batch("stepped_sim", "toy", batch)
        journal.close()
        records = list(MeasurementJournal(path).iter_records())
        assert sum(len(r["rows"]) for r in records) == 24  # chunks 1..3, not 0
        assert [1, 2, 3] not in [r["rows"][0] for r in records]  # chunk 0 absent

    def test_journal_opt_out_overrides_hub_default(self, tmp_path):
        hub = tmp_path / "hub"
        campaign = Campaign(_spec(hub_dir=str(hub)))
        campaign.run(runtime=RuntimeSpec(workers=1, journal_path=""))
        assert not os.path.exists(hub / "measurements.jsonl")
        # and the default (journal_path=None) does land in the hub
        campaign2 = Campaign(_spec(hub_dir=str(hub)))
        campaign2.run(runtime=RuntimeSpec(workers=1))
        assert os.path.exists(hub / "measurements.jsonl")

    def test_resume_equals_uninterrupted_with_zero_duplicates(self, tmp_path):
        journal = str(tmp_path / "measurements.jsonl")
        spec = _spec()

        # Run 1: crashes partway through (retries disabled: the "hardware"
        # fails permanently, like a killed process).
        crashed = Campaign(spec, platform=_CrashingPlatform(fail_after_rows=60))
        with pytest.raises(MeasurementError):
            crashed.run(
                runtime=RuntimeSpec(
                    workers=1, chunk_size=32, max_retries=0, journal_path=journal
                )
            )
        rows_before = sum(len(r["rows"]) for r in MeasurementJournal(journal).iter_records())
        assert 0 < rows_before <= 60

        # Run 2: fresh campaign, same journal -> resumes and completes.
        resumed = Campaign(spec)
        oracle = resumed.run(
            runtime=RuntimeSpec(workers=1, chunk_size=32, journal_path=journal)
        )

        # Control: uninterrupted run, no journal.
        control = Campaign(spec)
        control_oracle = control.run(runtime=RuntimeSpec(workers=1, chunk_size=32))

        # Bitwise-equal outcome...
        assert np.array_equal(
            oracle.predict("toy", QUERIES), control_oracle.predict("toy", QUERIES)
        )
        # ...with zero duplicate measurements: replay + new misses == one full
        # run's misses, and the journal holds each unique config exactly once.
        assert resumed.cache.replayed == rows_before
        assert resumed.cache.misses == control.cache.misses - rows_before
        keys = []
        for record in MeasurementJournal(journal).iter_records():
            for row in record["rows"]:
                keys.append((record["platform"], record["layer_type"],
                             tuple(record["params"]), tuple(row)))
        assert len(keys) == len(set(keys)) == control.cache.misses

    def test_replay_is_idempotent(self, tmp_path):
        journal = str(tmp_path / "j.jsonl")
        spec = _spec()
        Campaign(spec).run(runtime=RuntimeSpec(workers=1, journal_path=journal))
        cache = MeasurementCache()
        j = MeasurementJournal(journal)
        first = j.replay_into(cache)
        again = j.replay_into(cache)
        assert first["new"] == first["rows"] > 0
        assert again["new"] == 0
        assert cache.n_unique == first["rows"]


# ---------------------------------------------------------- journal integrity
class TestJournalCorruption:
    def _write_chunks(self, path, n_chunks=2, rows_per_chunk=3):
        with MeasurementJournal(path) as journal:
            for c in range(n_chunks):
                batch = ConfigBatch.from_columns(
                    {
                        "a": np.arange(1, rows_per_chunk + 1) + 10 * c,
                        "b": np.arange(1, rows_per_chunk + 1),
                    }
                )
                journal.append_chunk(
                    "stepped_sim", "toy", batch, np.full(rows_per_chunk, 1e-6 * (c + 1))
                )

    def test_corrupt_lines_skipped_with_warning(self, tmp_path):
        path = str(tmp_path / "j.jsonl")
        self._write_chunks(path)
        with open(path, "a") as f:
            f.write('{"v": 1, "platform": "x"\n')  # truncated mid-record
            f.write("not json at all\n")
            f.write('{"v": 1, "platform": "p", "layer_type": "toy", '
                    '"params": ["a"], "rows": [[1], [2]], "seconds": [1.0]}\n')  # mismatch
            f.write('{"v": 1, "platform": "p", "layer_type": "toy", '
                    '"params": ["a", "b"], "rows": [[1, 2], [3]], '
                    '"seconds": [1.0, 2.0]}\n')  # ragged rows (valid JSON)
            f.write('{"v": 1, "platform": "p", "layer_type": "toy", '
                    '"params": ["a", "b"], "rows": [[1, "x"]], '
                    '"seconds": [1.0]}\n')  # non-numeric cell (valid JSON+shape)
        cache = MeasurementCache()
        with pytest.warns(JournalCorruptionWarning):
            replay = MeasurementJournal(path).replay_into(cache)
        assert replay == {"records": 2, "rows": 6, "new": 6}
        assert cache.n_unique == 6 and cache.misses == 0

    def test_missing_journal_is_empty(self, tmp_path):
        replay = MeasurementJournal(str(tmp_path / "absent.jsonl")).replay_into(
            MeasurementCache()
        )
        assert replay == {"records": 0, "rows": 0, "new": 0}

    def test_float_round_trip_is_exact(self, tmp_path):
        path = str(tmp_path / "j.jsonl")
        rng = np.random.default_rng(0)
        seconds = rng.random(16) * 1e-3
        batch = ConfigBatch.from_columns({"a": np.arange(16), "b": np.arange(16)})
        with MeasurementJournal(path) as journal:
            journal.append_chunk("p", "toy", batch, seconds)
        cache = MeasurementCache()
        MeasurementJournal(path).replay_into(cache)
        times, miss_rows, _ = cache.lookup_many("p", "toy", batch)
        assert miss_rows.size == 0
        assert np.array_equal(times, seconds)


# ------------------------------------------------------------ fault tolerance
class _FlakyExecutor(SerialExecutor):
    """Fails the first ``n_failures`` submissions, then behaves serially."""

    def __init__(self, platform, n_failures: int) -> None:
        super().__init__(platform)
        self.n_failures = n_failures
        self.submissions = 0

    def submit(self, layer_type, batch):
        self.submissions += 1
        if self.n_failures > 0:
            self.n_failures -= 1
            future: Future = Future()
            future.set_exception(RuntimeError("transient worker death"))
            return future
        return super().submit(layer_type, batch)


class _StallingExecutor(SerialExecutor):
    """First submission never completes (hung worker); retries succeed."""

    def __init__(self, platform) -> None:
        super().__init__(platform)
        self.stalls = 1

    def submit(self, layer_type, batch):
        if self.stalls > 0:
            self.stalls -= 1
            return Future()  # never resolved
        return super().submit(layer_type, batch)


class _BreakingPoolExecutor(SerialExecutor):
    """Emulates an abrupt worker death: the first submission returns a failed
    future AND breaks the pool (submit raises, like BrokenProcessPool) until
    ``respawn`` rebuilds it."""

    workers = 2  # exercise the prefetch path

    def __init__(self, platform) -> None:
        super().__init__(platform)
        self.broken = False
        self.died = False
        self.respawns = 0

    def submit(self, layer_type, batch):
        if self.broken:
            raise RuntimeError("pool is broken")
        if not self.died:
            self.died = True
            self.broken = True
            future: Future = Future()
            future.set_exception(RuntimeError("worker died abruptly"))
            return future
        return super().submit(layer_type, batch)

    def respawn(self):
        self.broken = False
        self.respawns += 1


class TestRetryAndTimeout:
    def test_stale_timed_out_attempt_cannot_poison_the_journal(self, tmp_path):
        """A timed-out attempt that completes late must not leave its values
        as the journal's last word for the chunk — replay must yield exactly
        the values the run merged and trained on."""
        import threading
        import time as _time

        platform = SteppedSimPlatform()
        wrong = np.zeros(8)

        class _RunningFuture(Future):
            def cancel(self):
                return False  # like a ProcessPool future that is already executing

        class _StaleThenSlowRetry(SerialExecutor):
            workers = 2  # prefetch path, with journal callbacks

            def __init__(self):
                super().__init__(platform)
                self.calls = 0

            def submit(self, layer_type, batch):
                self.calls += 1
                if self.calls == 1:
                    stale: Future = _RunningFuture()
                    # completes mid-retry with values the run will discard
                    threading.Timer(0.1, stale.set_result, args=(wrong,)).start()
                    return stale
                _time.sleep(0.3)  # keep the retry slow so the stale completes first
                return super().submit(layer_type, batch)

        path = str(tmp_path / "j.jsonl")
        journal = MeasurementJournal(path)
        scheduler = MeasurementScheduler(
            _StaleThenSlowRetry(),
            journal=journal,
            chunk_size=8,
            max_retries=1,
            retry_backoff_s=0.001,
            chunk_timeout_s=0.03,
        )
        batch = ConfigBatch.from_columns({"a": np.arange(1, 9), "b": np.arange(1, 9)})
        y = scheduler.measure_batch("stepped_sim", "toy", batch)
        journal.close()
        expected = platform.measure_batch("toy", batch)
        assert np.array_equal(y, expected)
        # the stale callback journaled its record, then the merge loop
        # appended a superseding one...
        assert len(list(MeasurementJournal(path).iter_records())) == 2
        # ...and last-writer-wins replay recovers the merged values
        cache = MeasurementCache()
        MeasurementJournal(path).replay_into(cache)
        times, miss_rows, _ = cache.lookup_many("stepped_sim", "toy", batch)
        assert miss_rows.size == 0
        assert np.array_equal(times, expected)

    def test_broken_pool_is_respawned_and_chunk_retried(self):
        platform = SteppedSimPlatform()
        batch = ConfigBatch.from_columns({"a": np.arange(1, 33), "b": np.arange(1, 33)})
        executor = _BreakingPoolExecutor(platform)
        scheduler = MeasurementScheduler(
            executor, chunk_size=8, max_retries=1, retry_backoff_s=0.001
        )
        y = scheduler.measure_batch("stepped_sim", "toy", batch)
        assert np.array_equal(y, platform.measure_batch("toy", batch))
        assert executor.respawns == 1
        assert scheduler.stats.failures == 0
    def test_transient_failures_are_retried(self):
        platform = SteppedSimPlatform()
        batch = ConfigBatch.from_columns({"a": np.arange(1, 33), "b": np.arange(1, 33)})
        executor = _FlakyExecutor(platform, n_failures=2)
        scheduler = MeasurementScheduler(
            executor, chunk_size=8, max_retries=2, retry_backoff_s=0.001
        )
        y = scheduler.measure_batch("stepped_sim", "toy", batch)
        assert np.array_equal(y, platform.measure_batch("toy", batch))
        assert scheduler.stats.retries == 2
        assert scheduler.stats.failures == 0

    def test_retry_budget_exhaustion_raises(self):
        batch = ConfigBatch.from_columns({"a": np.arange(1, 9), "b": np.arange(1, 9)})
        executor = _FlakyExecutor(SteppedSimPlatform(), n_failures=100)
        scheduler = MeasurementScheduler(
            executor, chunk_size=8, max_retries=2, retry_backoff_s=0.001
        )
        with pytest.raises(MeasurementError):
            scheduler.measure_batch("stepped_sim", "toy", batch)
        assert scheduler.stats.failures == 1
        assert scheduler.stats.in_flight == 0

    def test_hung_chunk_times_out_and_retries(self):
        platform = SteppedSimPlatform()
        batch = ConfigBatch.from_columns({"a": np.arange(1, 9), "b": np.arange(1, 9)})
        scheduler = MeasurementScheduler(
            _StallingExecutor(platform),
            chunk_size=8,
            max_retries=1,
            retry_backoff_s=0.001,
            chunk_timeout_s=0.05,
        )
        y = scheduler.measure_batch("stepped_sim", "toy", batch)
        assert np.array_equal(y, platform.measure_batch("toy", batch))
        assert scheduler.stats.retries == 1


# ------------------------------------------------------------- pool teardown
class TestWedgedWorkerClose:
    def test_close_terminates_wedged_worker_within_bounded_time(self):
        """``close(wait=False)`` must actually abandon a wedged worker.

        ProcessPoolExecutor workers are non-daemon, and concurrent.futures
        joins them from an atexit hook — without an explicit ``terminate()``
        a worker stuck inside a measurement would hang the campaign process
        at interpreter exit.  The chunk below wedges its worker for ~60 s;
        close must come back (with every worker process dead) in seconds.
        """
        import time

        platform = SteppedSimPlatform(delay_s=1.0)
        pool = WorkerPool(platform.spawn_spec(), workers=1)
        batch = ConfigBatch.from_columns(
            {"a": np.arange(1, 61), "b": (np.arange(1, 61) % 32) + 1}
        )
        future = pool.submit("toy", batch)  # ~60 s of emulated measurement
        deadline = time.perf_counter() + 30
        while not pool._pool._processes and time.perf_counter() < deadline:
            time.sleep(0.05)  # wait for the worker process to exist
        procs = list(pool._pool._processes.values())
        assert procs, "worker process never spawned"

        t0 = time.perf_counter()
        pool.close()  # wait=False is the default
        elapsed = time.perf_counter() - t0
        assert elapsed < 15, f"close took {elapsed:.1f}s (wedged worker not abandoned)"
        for p in procs:
            p.join(timeout=10)
        assert all(not p.is_alive() for p in procs), "worker survived close"
        assert not future.done() or future.exception() is not None


# ------------------------------------------------------------ progress surface
class TestRunStats:
    def test_campaign_accounting(self, tmp_path):
        journal = str(tmp_path / "j.jsonl")
        c1 = Campaign(_spec())
        c1.run(runtime=RuntimeSpec(workers=1, chunk_size=16, journal_path=journal))
        s1 = c1.last_run_stats
        assert s1["measured"] == c1.cache.misses
        assert s1["cached"] == c1.cache.hits
        assert s1["chunks"] > 0 and s1["in_flight"] == 0
        assert s1["throughput_cfg_s"] > 0

        c2 = Campaign(_spec())
        oracle = c2.run(runtime=RuntimeSpec(workers=1, chunk_size=16, journal_path=journal))
        s2 = c2.last_run_stats
        assert s2["measured"] == 0
        assert s2["replayed"] == s1["measured"]
        assert oracle.run_stats == s2  # provenance rides on the oracle

    def test_stale_stats_not_attached_to_runtime_less_run(self):
        campaign = Campaign(_spec())
        campaign.run(runtime=RuntimeSpec(workers=1))
        assert campaign.last_run_stats is not None
        oracle = campaign.run()  # no runtime this time
        assert campaign.last_run_stats is None
        assert oracle.run_stats is None

    def test_render_mentions_core_counters(self):
        runtime = MeasurementRuntime(RuntimeSpec(workers=1), SteppedSimPlatform())
        runtime.stats.measured, runtime.stats.cached = 10, 4
        line = runtime.stats.render()
        assert "10 measured" in line and "4 cached" in line
        runtime.close()


# ----------------------------------------------------- feature-matrix memoize
class TestSamplingCurveFeatureMemo:
    def test_test_set_featurized_once(self):
        campaign = Campaign(_spec())
        test = [{"a": int(a), "b": int(b)} for a, b in zip(range(1, 21), range(32, 12, -1))]
        curve = campaign.sampling_curve("toy", [40, 60, 80], test)
        assert len(curve) == 3
        # one miss (first size), then one hit per remaining size
        assert campaign.cache.feature_hits == 2
        # the memoized matrix is exactly what a fresh featurization produces
        est = campaign.estimators["toy"]
        batch = ConfigBatch.from_dicts(test)
        X_memo = campaign.cache.lookup_features(
            campaign.platform.cache_key(), "toy", est.widths, True, batch
        )
        assert X_memo is not None
        assert np.array_equal(X_memo, est._features(batch, snap=True))
        # and the curve's metrics match an independent est.evaluate
        metrics = est.evaluate(campaign.platform, test)
        assert curve[-1]["mape"] == metrics["mape"]
        assert curve[-1]["rmspe"] == metrics["rmspe"]


# ------------------------------------------------------------- journal compact
class TestJournalCompaction:
    def _populate(self, path) -> MeasurementJournal:
        journal = MeasurementJournal(str(path))
        b1 = ConfigBatch.from_dicts([{"a": 1, "b": 2}, {"a": 3, "b": 4}])
        # same configs journaled again under reversed param order + a retry
        # that superseded {a:1,b:2} with a different final value
        b2 = ConfigBatch(
            params=("b", "a"), values=np.array([[2, 1], [6, 5]], dtype=np.int64)
        )
        journal.append_chunk("p", "toy", b1, np.array([1.0, 2.0]))
        journal.append_chunk("p", "toy", b2, np.array([1.5, 3.0]))
        journal.append_chunk("p", "toy", b1, np.array([1.75, 2.0]))
        from repro.core.batch import BlockBatch
        from repro.core.blocks import Block

        blocks = BlockBatch.from_blocks(
            [
                Block(kind="k", layers=(("toy", {"a": 2, "b": 2}),)),
                Block(kind="k", layers=(("toy", {"a": 4, "b": 4}),)),
            ]
        )
        journal.append_block_chunk("p", blocks, np.array([0.1, 0.2]))
        journal.append_block_chunk("p", blocks.take(np.array([0])), np.array([0.15]))
        journal.close()
        return journal

    def test_compact_preserves_replay_state_bitwise(self, tmp_path):
        journal = self._populate(tmp_path / "j.jsonl")
        before = MeasurementCache()
        MeasurementJournal(journal.path).replay_into(before)
        stats = MeasurementJournal(journal.path).compact()
        after = MeasurementCache()
        replay = MeasurementJournal(journal.path).replay_into(after)
        assert stats["records_in"] == 5 and stats["records_out"] == 3
        assert stats["rows_in"] == 9 and stats["rows_out"] == 5
        assert stats["bytes_out"] < stats["bytes_in"]
        # last-writer-wins values survive under first-occurrence keys
        assert after.lookup("p", "toy", {"a": 1, "b": 2}) == 1.75
        assert after.lookup("p", "toy", {"a": 3, "b": 4}) == 2.0
        assert after.lookup("p", "toy", {"a": 5, "b": 6}) == 3.0
        assert before._configs == after._configs if hasattr(before, "_configs") else True
        assert replay["rows"] == stats["rows_out"]

    def test_compact_is_idempotent(self, tmp_path):
        journal = self._populate(tmp_path / "j.jsonl")
        first = MeasurementJournal(journal.path).compact()
        second = MeasurementJournal(journal.path).compact()
        assert second["records_out"] == first["records_out"]
        assert second["rows_out"] == first["rows_out"]
        assert second["bytes_out"] == first["bytes_out"]

    def test_compact_missing_file_is_a_no_op(self, tmp_path):
        stats = MeasurementJournal(str(tmp_path / "absent.jsonl")).compact()
        assert stats["records_in"] == 0 and stats["records_out"] == 0

    def test_hub_gc_drops_superseded_artifacts_keeps_latest(self, tmp_path):
        from repro.api import EstimatorHub, PerfOracle
        from repro.checkpoint.manager import journal_path

        hub = EstimatorHub(str(tmp_path), keep=4)
        campaign = Campaign(_spec())
        oracle = campaign.run()
        for _ in range(3):
            oracle.save(hub, "stepped_sim")
        slot = os.path.join(str(tmp_path), "stepped_sim", "toy")
        os.makedirs(os.path.join(slot, "step_000000042.tmp"))
        journal = MeasurementJournal(journal_path(str(tmp_path)))
        batch = ConfigBatch.from_dicts([{"a": 1, "b": 1}])
        journal.append_chunk("stepped_sim", "toy", batch, np.array([1e-6]))
        journal.append_chunk("stepped_sim", "toy", batch, np.array([2e-6]))
        journal.close()

        ref = oracle.predict("toy", [{"a": 7, "b": 3}])
        out = hub.gc(keep=1)
        assert out["steps_removed"] == 2 and out["tmp_removed"] == 1
        assert out["journal"]["records_out"] == 1
        assert sorted(os.listdir(slot)) == ["step_000000003"]
        reloaded = PerfOracle.load(hub, "stepped_sim")
        assert np.array_equal(reloaded.predict("toy", [{"a": 7, "b": 3}]), ref)


# --------------------------------------------------------- executor-side costs
class TestExecutorSideCostTimer:
    def test_serial_executor_reports_exec_seconds(self):
        scheduler = MeasurementScheduler(SerialExecutor(SteppedSimPlatform()))
        batch = ConfigBatch.from_columns(
            {"a": np.arange(1, 33), "b": (np.arange(1, 33) % 32) + 1}
        )
        scheduler.measure_batch("stepped_sim", "toy", batch)
        items, spent = scheduler._exec_costs["configs"]
        assert items == 32 and spent > 0.0
        assert scheduler.stats.exec_seconds == spent
        assert scheduler.stats.snapshot()["exec_seconds"] == spent
        # exec time excludes dispatch overhead, so it never exceeds wall
        assert spent <= scheduler._path_costs["configs"][1]

    def test_exec_costs_preferred_over_wall_costs(self):
        scheduler = MeasurementScheduler(SerialExecutor(SteppedSimPlatform()))
        scheduler._path_costs["configs"] = [10, 100.0]  # wall says 1 chunk=0
        scheduler._exec_costs["configs"] = [100, 1.0]  # exec says 10 ms/item
        assert scheduler.effective_chunk_size("configs") == 100
        # no exec data for blocks: falls back to the wall pool untouched
        scheduler._path_costs["blocks"] = [10, 20.0]
        assert scheduler.effective_chunk_size("blocks") == 1

    def test_bare_array_results_still_accepted(self):
        """Third-party executors may return arrays without a timing tuple."""

        class BareExecutor(SerialExecutor):
            def submit(self, layer_type, batch):
                future = Future()
                future.set_result(
                    np.asarray(
                        self.platform.measure_batch(layer_type, batch),
                        dtype=np.float64,
                    )
                )
                return future

        platform = SteppedSimPlatform()
        batch = ConfigBatch.from_columns(
            {"a": np.arange(1, 17), "b": (np.arange(1, 17) % 32) + 1}
        )
        scheduler = MeasurementScheduler(BareExecutor(platform))
        y = scheduler.measure_batch("stepped_sim", "toy", batch)
        assert np.array_equal(y, platform.measure_batch("toy", batch))
        assert scheduler._exec_costs["configs"] == [0, 0.0]
        assert scheduler.stats.exec_seconds == 0.0

    def test_worker_pool_reports_exec_seconds_across_processes(self):
        platform = SteppedSimPlatform(delay_s=0.001)
        batch = ConfigBatch.from_columns(
            {"a": np.arange(1, 17), "b": (np.arange(1, 17) % 32) + 1}
        )
        pool = WorkerPool(platform.spawn_spec(), workers=2)
        try:
            scheduler = MeasurementScheduler(pool, chunk_size=8)
            y = scheduler.measure_batch("stepped_sim", "toy", batch)
        finally:
            pool.close()
        assert np.array_equal(y, platform.measure_batch("toy", batch))
        items, spent = scheduler._exec_costs["configs"]
        assert items == 16 and spent >= 16 * 0.001
