"""Per-architecture smoke tests: reduced config, one train + decode step on CPU.

Asserts output shapes and finiteness for every assigned architecture, plus
family-specific behaviours (MoE routing, SSM decode equivalence, M-RoPE,
enc-dec cross attention).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.distributed import single_device_rules, use_rules
from repro.models import transformer as T
from repro.models.config import reduced
from repro.models.kvcache import init_cache

B, S = 2, 32


def _batch(cfg):
    batch = {
        "tokens": jnp.ones((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        nv = 8
        batch["vision_embeds"] = jnp.ones((B, nv, cfg.d_model), jnp.float32) * 0.01
        batch["positions"] = jnp.broadcast_to(jnp.arange(S + nv)[None, None], (3, B, S + nv))
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.01
    return batch


@pytest.fixture(scope="module")
def rules():
    return single_device_rules()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_and_decode_smoke(arch, rules):
    cfg = reduced(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    with use_rules(rules):
        loss, metrics = jax.jit(lambda p, b: T.loss_fn(p, cfg, b))(params, batch)
        assert jnp.isfinite(loss), arch
        assert float(loss) > 0

        cache = init_cache(cfg, B, 64)
        if cfg.family == "audio":
            cache.pop("enc_kv")
        dec = {"tokens": jnp.ones((B, 1), jnp.int32)}
        if cfg.family == "audio":
            dec["frames"] = batch["frames"]
        logits, _, new_cache = jax.jit(lambda p, b, c: T.forward(p, cfg, b, c))(params, dec, cache)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits))), arch
        assert int(new_cache["len"]) == 1


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-780m"])
def test_loss_decreases(arch, rules):
    """A few optimizer steps on repeated data reduce the loss."""
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.steps import make_train_step

    cfg = reduced(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10)))
    with use_rules(rules):
        first = None
        for _ in range(8):
            params, opt, m = step(params, opt, batch)
            first = first if first is not None else float(m["loss"])
        assert float(m["loss"]) < first


def test_ssm_prefill_decode_equivalence(rules):
    """Decoding token-by-token == prefill over the same sequence (SSM)."""
    cfg = reduced(get_config("mamba2-780m"))
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(np.random.default_rng(0).integers(1, cfg.vocab, (1, 8)), jnp.int32)
    with use_rules(rules):
        logits_all, _, _ = T.forward(params, cfg, {"tokens": toks})
        cache = init_cache(cfg, 1, 16)
        outs = []
        for t in range(8):
            lg, _, cache = T.forward(params, cfg, {"tokens": toks[:, t : t + 1]}, cache)
            outs.append(lg[:, 0])
        logits_dec = jnp.stack(outs, axis=1)
    # bf16 accumulation order differs between the batched prefill and the
    # step-by-step decode; tolerance reflects that, and greedy decisions agree.
    np.testing.assert_allclose(
        np.asarray(logits_all, np.float32), np.asarray(logits_dec, np.float32), atol=8e-2, rtol=5e-2
    )
    assert bool(
        (jnp.argmax(logits_all, -1) == jnp.argmax(logits_dec, -1)).all()
    ), "greedy tokens diverged between prefill and decode"


def test_attention_prefill_decode_equivalence(rules):
    """Same check through the KV-cache path (dense GQA arch)."""
    cfg = reduced(get_config("internlm2-1.8b"))
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    toks = jnp.asarray(np.random.default_rng(1).integers(1, cfg.vocab, (1, 8)), jnp.int32)
    with use_rules(rules):
        logits_all, _, _ = T.forward(params, cfg, {"tokens": toks})
        cache = init_cache(cfg, 1, 16)
        outs = []
        for t in range(8):
            lg, _, cache = T.forward(params, cfg, {"tokens": toks[:, t : t + 1]}, cache)
            outs.append(lg[:, 0])
        logits_dec = jnp.stack(outs, axis=1)
    # bf16 accumulation order differs between the batched prefill and the
    # step-by-step decode; tolerance reflects that, and greedy decisions agree.
    np.testing.assert_allclose(
        np.asarray(logits_all, np.float32), np.asarray(logits_dec, np.float32), atol=8e-2, rtol=5e-2
    )
    assert bool(
        (jnp.argmax(logits_all, -1) == jnp.argmax(logits_dec, -1)).all()
    ), "greedy tokens diverged between prefill and decode"


def test_moe_routes_to_multiple_experts(rules):
    from repro.models.moe import moe_block

    cfg = reduced(get_config("olmoe-1b-7b"))
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    p = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, cfg.d_model)) * 0.1
    with use_rules(rules):
        y, aux = jax.jit(lambda x, p: moe_block(x, p, cfg))(x, p)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0.5  # ~1.0 for balanced routing


def test_mrope_equals_rope_for_text(rules):
    from repro.models.layers import apply_mrope, apply_rope

    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, 4, 32))
    pos = jnp.arange(8)[None, :].repeat(2, 0)
    mpos = jnp.broadcast_to(pos[None], (3, 2, 8))
    r1 = apply_rope(x, pos, 1e4)
    r2 = apply_mrope(x, mpos, 1e4, (4, 6, 6))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-6)


def test_param_count_sanity():
    """Full configs land near their nameplate sizes."""
    approx = {
        "qwen2-1.5b": 1.5e9,
        "granite-20b": 20e9,
        "granite-34b": 34e9,
        "internlm2-1.8b": 1.8e9,
        "mamba2-780m": 0.78e9,
        "qwen3-moe-235b-a22b": 235e9,
        "olmoe-1b-7b": 7e9,
        "zamba2-2.7b": 2.7e9,
    }
    for arch, expect in approx.items():
        n = get_config(arch).param_count()
        assert 0.55 * expect < n < 1.6 * expect, (arch, n, expect)
