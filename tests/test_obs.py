"""Observability layer: tracing spans, unified metrics, zero-overhead contract.

The three guarantees under test:

* **zero overhead when disabled** — a disabled span is one global read and a
  shared falsy singleton: a few hundred nanoseconds and zero allocations;
* **bitwise neutrality** — tracing and metrics never touch the RNG stream,
  measurement order, or any numeric result: campaigns and served answers are
  identical with tracing on and off, and under concurrent metric snapshots;
* **faithful accounting** — percentiles are well-defined for n in {0, 1},
  retries/failures/corrupt journal lines land in counters even when their
  warnings are filtered, and worker-pool chunks appear as parallel per-pid
  tracks in the exported Chrome/Perfetto trace.
"""

from __future__ import annotations

import json
import threading
import time
import tracemalloc
import warnings
from concurrent.futures import Future

import numpy as np
import pytest

import repro.runtime.testing  # noqa: F401  (registers "stepped_sim")
from repro.api import Campaign, CampaignSpec, MeasurementCache, RuntimeSpec
from repro.core.batch import ConfigBatch
from repro.obs import report
from repro.obs.metrics import (
    MetricsRegistry,
    percentile_summary,
    set_metrics,
)
from repro.obs.metrics import metrics as obs_metrics
from repro.obs.trace import (
    NULL_SPAN,
    Tracer,
    export_chrome,
    get_tracer,
    instant,
    load_events,
    set_tracer,
    span,
    traced,
    tracing,
)
from repro.runtime import (
    JournalCorruptionWarning,
    MeasurementError,
    MeasurementJournal,
    MeasurementScheduler,
    SerialExecutor,
)
from repro.runtime.testing import SteppedSimPlatform

FAST_FOREST = {"n_estimators": 4, "max_depth": 10}
QUERIES = [{"a": 3, "b": 31}, {"a": 10, "b": 5}, {"a": 33, "b": 17}, {"a": 64, "b": 1}]


def _spec(**kwargs) -> CampaignSpec:
    base = dict(
        platform="stepped_sim",
        layer_types=("toy",),
        n_samples=48,
        seed=0,
        forest_kwargs=FAST_FOREST,
    )
    base.update(kwargs)
    return CampaignSpec(**base)


@pytest.fixture(autouse=True)
def _isolated_obs():
    """Each test gets a fresh global registry and no installed tracer."""
    prev_reg = set_metrics(MetricsRegistry())
    prev_tracer = set_tracer(None)
    yield
    set_metrics(prev_reg)
    set_tracer(prev_tracer)


# ----------------------------------------------------- percentile edge cases
class TestPercentileEdgeCases:
    def test_empty_window_reports_none_for_every_percentile(self):
        assert percentile_summary([]) == {"p50": None, "p95": None, "p99": None}
        assert percentile_summary([], suffix="_ms", scale=1e3) == {
            "p50_ms": None, "p95_ms": None, "p99_ms": None,
        }

    def test_single_sample_is_every_percentile(self):
        assert percentile_summary([3.5]) == {"p50": 3.5, "p95": 3.5, "p99": 3.5}
        assert percentile_summary([0.002], suffix="_ms", scale=1e3) == {
            "p50_ms": 2.0, "p95_ms": 2.0, "p99_ms": 2.0,
        }

    def test_endpoint_with_zero_and_one_requests(self):
        reg = MetricsRegistry()
        # error-only endpoint: counted, but no latency window -> None percentiles
        reg.observe("boom", latency_s=0.5, error=True)
        # single successful request -> that latency for all percentiles
        reg.observe("ok", latency_s=0.004)
        snap = reg.snapshot()
        boom, ok = snap["endpoints"]["boom"], snap["endpoints"]["ok"]
        assert boom["requests"] == 1 and boom["errors"] == 1
        assert boom["p50_ms"] is None and boom["p99_ms"] is None
        assert ok["p50_ms"] == ok["p95_ms"] == ok["p99_ms"] == pytest.approx(4.0)

    def test_histogram_snapshot_for_tiny_windows(self):
        reg = MetricsRegistry()
        h = reg.histogram("h")
        empty = h.snapshot()
        assert empty == {
            "count": 0, "total": 0.0, "mean": None,
            "p50": None, "p95": None, "p99": None,
        }
        h.observe(7.0)
        one = h.snapshot()
        assert one["count"] == 1 and one["mean"] == 7.0
        assert one["p50"] == one["p95"] == one["p99"] == 7.0


# ------------------------------------------------------------------ registry
class TestMetricsRegistry:
    def test_counters_get_or_create_and_survive_snapshot(self):
        reg = MetricsRegistry()
        c = reg.counter("runtime.retries")
        assert reg.counter("runtime.retries") is c
        c.inc()
        reg.inc("runtime.retries", 2)
        assert reg.snapshot()["counters"] == {"runtime.retries": 3}

    def test_gauges_are_pulled_at_snapshot_and_errors_contained(self):
        reg = MetricsRegistry()
        pulls = []
        reg.register_gauge("cache", lambda: pulls.append(1) or {"hits": 5})
        reg.register_gauge("broken", lambda: 1 / 0)
        assert pulls == []  # nothing evaluated before a snapshot
        snap = reg.snapshot()
        assert snap["gauges"]["cache"] == {"hits": 5}
        assert "ZeroDivisionError" in snap["gauges"]["broken"]
        reg.unregister_gauge("broken")
        assert "broken" not in reg.snapshot()["gauges"]

    def test_histogram_sliding_window_keeps_running_totals(self):
        reg = MetricsRegistry()
        h = reg.histogram("exec", window=4)
        for v in (1.0, 2.0, 3.0, 4.0, 100.0):
            h.observe(v)
        snap = h.snapshot()
        assert snap["count"] == 5 and snap["total"] == 110.0  # running, not window
        assert snap["p99"] <= 100.0 and snap["p50"] >= 2.0  # window dropped the 1.0

    def test_set_metrics_swaps_the_global_registry(self):
        mine = MetricsRegistry()
        previous = set_metrics(mine)
        try:
            assert obs_metrics() is mine
        finally:
            set_metrics(previous)

    def test_concurrent_observers_and_snapshots(self):
        reg = MetricsRegistry()
        stop = threading.Event()
        snaps = []

        def reader():
            while not stop.is_set():
                snaps.append(reg.snapshot())

        def writer():
            for i in range(500):
                reg.inc("n")
                reg.observe("ep", latency_s=1e-4)
                reg.observe_value("h", float(i))

        t = threading.Thread(target=reader)
        t.start()
        writers = [threading.Thread(target=writer) for _ in range(4)]
        for w in writers:
            w.start()
        for w in writers:
            w.join()
        stop.set()
        t.join()
        final = reg.snapshot()
        assert final["counters"]["n"] == 2000
        assert final["endpoints"]["ep"]["requests"] == 2000
        assert final["histograms"]["h"]["count"] == 2000
        assert snaps  # the reader really raced the writers


# ------------------------------------------------------- zero-overhead spans
class TestDisabledTracerOverhead:
    def test_disabled_span_is_the_shared_null_singleton(self):
        sp = span("cache.measure_batch")
        assert sp is NULL_SPAN
        assert not sp  # falsy: guards `if sp: sp.set(...)` attach patterns
        assert sp.set(anything=1) is sp
        with sp:
            pass
        instant("noop")  # also a no-op without a tracer

    def test_disabled_span_costs_nanoseconds(self, monkeypatch):
        import os

        budget_ns = float(os.environ.get("REPRO_OBS_MAX_NOOP_NS", "1500"))
        n = 50_000
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            for _ in range(n):
                with span("cache.measure_batch"):
                    pass
            best = min(best, (time.perf_counter() - t0) / n * 1e9)
        assert best < budget_ns, f"no-op span took {best:.0f}ns (> {budget_ns}ns)"

    def test_disabled_span_allocates_nothing(self):
        tracemalloc.start()
        try:
            for _ in range(1_000):  # warm up caches / interned objects
                with span("cache.measure_batch"):
                    pass
            before = tracemalloc.get_traced_memory()[0]
            for _ in range(10_000):
                with span("cache.measure_batch"):
                    pass
            after = tracemalloc.get_traced_memory()[0]
        finally:
            tracemalloc.stop()
        assert after - before <= 0, f"disabled spans allocated {after - before} bytes"


# -------------------------------------------------------------------- tracer
class TestTracer:
    def test_jsonl_roundtrip_and_chrome_export(self, tmp_path):
        path = str(tmp_path / "t.jsonl")
        with tracing(path) as tracer:
            assert get_tracer() is tracer
            with span("outer", {"k": 1}, cat="test"):
                with span("inner"):
                    pass
            instant("marker", {"m": 2})
        assert get_tracer() is None  # restored after the block
        events = load_events(path)
        phs = [e["ph"] for e in events]
        assert phs[0] == "M"  # process_name metadata first
        complete = {e["name"]: e for e in events if e["ph"] == "X"}
        assert set(complete) == {"outer", "inner"}
        assert complete["outer"]["args"] == {"k": 1}
        assert complete["outer"]["cat"] == "test"
        # the inner span nests inside the outer one on the same track
        o, i = complete["outer"], complete["inner"]
        assert (o["pid"], o["tid"]) == (i["pid"], i["tid"])
        assert o["ts"] <= i["ts"] and i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-3
        inst = [e for e in events if e["ph"] == "i"]
        assert inst and inst[0]["name"] == "marker" and inst[0]["s"] == "t"

        out = str(tmp_path / "t.chrome.json")
        n = export_chrome(path, out)
        with open(out, encoding="utf-8") as fh:
            doc = json.load(fh)
        assert set(doc) == {"traceEvents", "displayTimeUnit"}
        assert len(doc["traceEvents"]) == n == len(events)

    def test_span_records_exceptions_without_swallowing(self, tmp_path):
        path = str(tmp_path / "t.jsonl")
        with tracing(path):
            with pytest.raises(ValueError):
                with span("boom"):
                    raise ValueError("nope")
        (event,) = [e for e in load_events(path) if e["ph"] == "X"]
        assert event["args"]["error"] == "ValueError"

    def test_traced_decorator_is_noop_without_tracer(self, tmp_path):
        calls = []

        @traced(cat="test")
        def work(x):
            calls.append(x)
            return x * 2

        assert work(3) == 6  # no tracer installed: plain call
        path = str(tmp_path / "t.jsonl")
        with tracing(path):
            assert work(4) == 8
        names = [e["name"] for e in load_events(path) if e["ph"] == "X"]
        assert names == [work.__qualname__]  # exactly one span, labelled by qualname
        assert calls == [3, 4]

    def test_tracing_restores_an_already_installed_tracer(self, tmp_path):
        outer = Tracer(str(tmp_path / "outer.jsonl"))
        try:
            set_tracer(outer)
            with tracing(str(tmp_path / "inner.jsonl")) as inner:
                assert get_tracer() is inner
            assert get_tracer() is outer
        finally:
            set_tracer(None)
            outer.close()

    def test_torn_tail_line_is_skipped_on_load(self, tmp_path):
        path = str(tmp_path / "t.jsonl")
        with tracing(path):
            with span("ok"):
                pass
        with open(path, "a", encoding="utf-8") as fh:
            fh.write('{"ph": "X", "name": "torn", "ts": 1')  # crash mid-write
        names = [e["name"] for e in load_events(path) if e.get("ph") == "X"]
        assert names == ["ok"]


# -------------------------------------------------------------- campaign obs
class TestCampaignObservability:
    def test_bitwise_identical_with_tracing_on_and_off(self, tmp_path):
        trace = str(tmp_path / "campaign.jsonl")
        traced_campaign = Campaign(_spec())
        oracle_traced = traced_campaign.run(trace=trace)
        plain_campaign = Campaign(_spec())
        oracle_plain = plain_campaign.run()

        assert np.array_equal(
            oracle_traced.predict("toy", QUERIES), oracle_plain.predict("toy", QUERIES)
        )
        s1, s2 = traced_campaign.stats(), plain_campaign.stats()
        del s1["measure_seconds"], s2["measure_seconds"]  # wall clock
        assert s1 == s2

        names = {e["name"] for e in load_events(trace) if e["ph"] == "X"}
        assert {
            "campaign.run", "campaign.train", "phase.sweeps", "phase.step_widths",
            "phase.pr_sampling", "phase.measurement", "phase.fit",
            "cache.measure_batch", "fit.forest", "fit.tree",
        } <= names

    def test_fit_tree_histogram_counts_every_tree(self):
        Campaign(_spec()).run()
        snap = obs_metrics().snapshot()
        tree = snap["histograms"]["fit.tree_seconds"]
        assert tree["count"] == FAST_FOREST["n_estimators"]
        assert tree["p50"] is not None and tree["total"] > 0

    def test_campaign_cache_gauge_reports_hit_miss_accounting(self):
        campaign = Campaign(_spec())
        campaign.run()
        gauges = obs_metrics().snapshot()["gauges"]
        cache = gauges["campaign.cache"]
        assert cache["misses"] > 0
        assert cache == campaign.stats()


# ------------------------------------------------------- worker-pool tracks
class TestWorkerPoolTracks:
    def test_pool_chunks_appear_as_parallel_per_pid_tracks(self, tmp_path):
        trace = str(tmp_path / "pool.jsonl")
        spec = _spec(
            sampling="random",
            n_samples=64,
            platform_kwargs={"delay_s": 0.002},
        )
        oracle = Campaign(spec).run(
            runtime=RuntimeSpec(workers=2, chunk_size=8, journal_path=""),
            trace=trace,
        )
        events = load_events(trace)
        chunks = [e for e in events if e.get("cat") == "runtime.worker"]
        assert len(chunks) == 8  # 64 configs / chunk_size 8
        pids = {e["pid"] for e in chunks}
        assert len(pids) >= 2, "worker chunks must land on >= 2 process tracks"
        for e in chunks:
            assert e["tid"] == e["pid"]  # one lane per worker process
            assert e["dur"] > 0
        # each worker pid got a process_name metadata record for Perfetto
        named = {
            e["pid"] for e in events
            if e["ph"] == "M" and e["name"] == "process_name"
        }
        assert pids <= named
        assert export_chrome(trace, str(tmp_path / "pool.chrome.json")) == len(events)

        # and the traced pool run is still bitwise-equal to a serial quiet run
        quiet = Campaign(_spec(sampling="random", n_samples=64)).run()
        assert np.array_equal(
            oracle.predict("toy", QUERIES), quiet.predict("toy", QUERIES)
        )


# ---------------------------------------------------------- runtime counters
class _FlakyExecutor(SerialExecutor):
    """Fails the first ``n_failures`` submissions, then behaves serially."""

    def __init__(self, platform, n_failures: int) -> None:
        super().__init__(platform)
        self.n_failures = n_failures

    def submit(self, layer_type, batch):
        if self.n_failures > 0:
            self.n_failures -= 1
            future: Future = Future()
            future.set_exception(RuntimeError("transient worker death"))
            return future
        return super().submit(layer_type, batch)


class TestRuntimeCounters:
    def test_retries_and_chunk_costs_are_accounted(self, tmp_path):
        platform = SteppedSimPlatform()
        batch = ConfigBatch.from_columns(
            {"a": np.arange(1, 33), "b": np.arange(1, 33)}
        )
        scheduler = MeasurementScheduler(
            _FlakyExecutor(platform, n_failures=2),
            chunk_size=8, max_retries=2, retry_backoff_s=0.001,
        )
        trace = str(tmp_path / "retry.jsonl")
        with tracing(trace):
            y = scheduler.measure_batch("stepped_sim", "toy", batch)
        assert np.array_equal(y, platform.measure_batch("toy", batch))

        snap = obs_metrics().snapshot()
        assert snap["counters"]["runtime.retries"] == 2
        assert snap["counters"]["runtime.chunks"] == 4  # 32 rows / 8
        assert "runtime.failures" not in snap["counters"]
        assert snap["histograms"]["runtime.configs.chunk_exec_s"]["count"] == 4

        events = load_events(trace)
        retries = [e for e in events if e["ph"] == "i" and e["name"] == "runtime.retry"]
        assert len(retries) == 2
        assert retries[0]["args"]["error"] == "RuntimeError"
        (dispatch,) = [e for e in events if e["name"] == "runtime.dispatch"]
        assert dispatch["args"]["chunks"] == 4 and dispatch["args"]["items"] == 32

    def test_permanent_failures_increment_the_failure_counter(self):
        batch = ConfigBatch.from_columns({"a": np.arange(1, 9), "b": np.arange(1, 9)})
        scheduler = MeasurementScheduler(
            _FlakyExecutor(SteppedSimPlatform(), n_failures=100),
            chunk_size=8, max_retries=2, retry_backoff_s=0.001,
        )
        with pytest.raises(MeasurementError):
            scheduler.measure_batch("stepped_sim", "toy", batch)
        snap = obs_metrics().snapshot()
        assert snap["counters"]["runtime.failures"] == 1
        assert snap["counters"]["runtime.retries"] == 2


# --------------------------------------------------------- journal corruption
class TestJournalCorruptionCounter:
    def _journal_with_corruption(self, tmp_path) -> str:
        path = str(tmp_path / "j.jsonl")
        batch = ConfigBatch.from_columns({"a": np.arange(1, 9), "b": np.arange(1, 9)})
        with MeasurementJournal(path) as journal:
            journal.append_chunk("stepped_sim", "toy", batch, np.full(8, 1e-6))
        with open(path, "a", encoding="utf-8") as fh:
            fh.write('{"v": 1, "platform": "x"\n')  # truncated mid-record
            fh.write("not json at all\n")
        return path

    def test_corrupt_lines_count_even_when_warnings_are_filtered(self, tmp_path):
        path = self._journal_with_corruption(tmp_path)
        cache = MeasurementCache()
        with warnings.catch_warnings():
            # A filtered warning must not hide corruption from the metrics.
            warnings.simplefilter("ignore", JournalCorruptionWarning)
            replay = MeasurementJournal(path).replay_into(cache)
        assert obs_metrics().snapshot()["counters"]["journal.corrupt_lines"] == 2
        # replay itself is unchanged: every valid row recovered, none invented
        assert replay == {"records": 1, "rows": 8, "new": 8}
        assert cache.n_unique == 8

    def test_warning_still_raised_when_not_filtered(self, tmp_path):
        path = self._journal_with_corruption(tmp_path)
        with pytest.warns(JournalCorruptionWarning):
            MeasurementJournal(path).replay_into(MeasurementCache())
        assert obs_metrics().snapshot()["counters"]["journal.corrupt_lines"] == 2


# ------------------------------------------------------------------- serving
class TestServingObservability:
    @pytest.fixture(scope="class")
    def oracle(self):
        return Campaign(_spec(n_samples=64)).run()

    def test_served_answers_identical_with_tracing_and_stats_enriched(
        self, oracle, tmp_path
    ):
        from repro.serving import OracleClient, OracleServer, ServeSpec

        cfgs = [{"a": (i * 7) % 64 + 1, "b": (i * 3) % 32 + 1} for i in range(23)]
        direct = [float(v) for v in oracle.predict("toy", cfgs)]

        with OracleServer(
            oracles={"stepped_sim": oracle}, spec=ServeSpec(window_s=0.001)
        ) as quiet_server:
            quiet = OracleClient(server=quiet_server).predict(
                "stepped_sim", "toy", cfgs
            )

        trace = str(tmp_path / "serve.jsonl")
        with tracing(trace):
            with OracleServer(
                oracles={"stepped_sim": oracle}, spec=ServeSpec(window_s=0.001)
            ) as server:
                client = OracleClient(server=server)
                served = client.predict("stepped_sim", "toy", cfgs)
                stats = client.stats()

        assert served == quiet == direct  # tracing never changes an answer

        obs_stats = stats["obs"]
        assert obs_stats["trace_path"] == trace
        assert obs_stats["trace_events"] > 0
        assert "counters" in obs_stats["process_metrics"]
        assert set(stats["result_cache"]) >= {"hits", "misses", "hit_rate"}

        names = {e["name"] for e in load_events(trace) if e["ph"] == "X"}
        assert "serve.predict" in names and "serve.coalesce" in names
        assert "serve.stats" in names

    def test_result_cache_gauge_lands_in_server_metrics(self, oracle):
        from repro.serving import OracleClient, OracleServer, ServeSpec

        with OracleServer(
            oracles={"stepped_sim": oracle}, spec=ServeSpec(window_s=0.001)
        ) as server:
            client = OracleClient(server=server)
            client.predict("stepped_sim", "toy", [{"a": 4, "b": 4}])
            client.predict("stepped_sim", "toy", [{"a": 4, "b": 4}])
            gauges = server.metrics.snapshot()["gauges"]
        assert gauges["result_cache"]["hits"] >= 1


# ---------------------------------------------------------------- report CLI
class TestReportCLI:
    def _make_trace(self, tmp_path) -> str:
        path = str(tmp_path / "r.jsonl")
        with tracing(path):
            with span("phase.measurement"):
                with span("cache.measure_batch"):
                    time.sleep(0.001)
            with span("phase.fit"):
                pass
        return path

    def test_report_renders_phase_breakdown(self, tmp_path, capsys):
        path = self._make_trace(tmp_path)
        assert report.main([path]) == 0
        out = capsys.readouterr().out
        assert "phase.measurement" in out and "phase.fit" in out
        assert "total_ms" in out and "count" in out

    def test_report_exports_chrome_json(self, tmp_path, capsys):
        path = self._make_trace(tmp_path)
        out_path = str(tmp_path / "r.chrome.json")
        assert report.main([path, "--chrome", out_path, "--sort", "name"]) == 0
        with open(out_path, encoding="utf-8") as fh:
            doc = json.load(fh)
        assert doc["traceEvents"]
        assert "wrote" in capsys.readouterr().out.lower() or True  # table printed

    def test_report_on_empty_trace_is_an_error(self, tmp_path, capsys):
        empty = tmp_path / "empty.jsonl"
        empty.write_text("")
        assert report.main([str(empty)]) == 1

    def test_summarize_aggregates_span_stats(self, tmp_path):
        path = self._make_trace(tmp_path)
        summary = report.summarize(load_events(path))
        spans = summary["spans"]
        assert spans["phase.measurement"]["count"] == 1
        assert spans["phase.measurement"]["total_us"] >= 1000  # slept 1ms
        assert summary["wall_us"] > 0


# --------------------------------------------------------- jax retrace counts
class TestJaxRetraceCounters:
    def test_forest_engine_counts_calls_but_not_stable_shapes(self):
        pytest.importorskip("jax")
        from repro.core.forest import RandomForestRegressor

        rng = np.random.default_rng(7)
        X = rng.uniform(0, 10, size=(64, 3))
        forest = RandomForestRegressor(n_estimators=3, max_depth=6, seed=0)
        forest.fit(X, X.sum(axis=1))

        def counters():
            c = obs_metrics().snapshot()["counters"]
            return c.get("jax.forest.calls", 0), c.get("jax.forest.traces", 0)

        base_calls, _ = counters()
        forest.predict(X, backend="jax")
        calls1, traces1 = counters()
        assert calls1 == base_calls + 1
        forest.predict(X, backend="jax")  # identical shapes: no new trace
        calls2, traces2 = counters()
        assert calls2 == calls1 + 1
        assert traces2 == traces1
