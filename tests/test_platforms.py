"""Accelerator platforms: timing models + knowledge tiers + Algorithm 1 E2E."""

import numpy as np
import pytest

from repro.accelerators import TPUv5eSim, UltraTrailSim, VTASim, XLACPUPlatform
from repro.core import prs, steps, sweeps


class TestUltraTrail:
    def test_white_box_widths(self):
        ut = UltraTrailSim()
        assert ut.known_step_widths("conv1d")["C"] == 8
        assert ut.known_step_widths("conv1d")["K"] == 8

    def test_sweeps_confirm_documented_widths(self):
        """Black-box treatment of the white-box sim recovers 8/8 (Fig. 2 analog)."""
        ut = UltraTrailSim()
        sw = sweeps.run_sweeps(ut, "conv1d", params=("C", "K", "C_w"), n_points=56)
        W = steps.determine_step_widths(sw)
        assert W["C"] == 8 and W["K"] == 8 and W["C_w"] == 1

    def test_same_step_same_time(self):
        """All configs within one step cost the same (paper Sec. 3.3)."""
        ut = UltraTrailSim()
        base = ut.defaults("conv1d")
        times = {ut.measure("conv1d", {**base, "C": c}) for c in (17, 20, 24)}
        assert len(times) == 1
        assert ut.measure("conv1d", {**base, "C": 25}) > next(iter(times))


class TestVTA:
    def test_gray_box_confirms_16(self):
        vta = VTASim()
        W, _, n_meas = sweeps.discover_step_widths(vta, "fully_connected")
        assert W == {"in": 16, "out": 16}
        assert n_meas > 0  # gray box had to sweep

    def test_conv2d_widths(self):
        vta = VTASim()
        W, _, _ = sweeps.discover_step_widths(vta, "conv2d")
        assert W["C"] == 16 and W["K"] == 16


class TestTPUv5e:
    def test_knowledge_tiers(self):
        white = TPUv5eSim(knowledge="white")
        gray = TPUv5eSim(knowledge="gray")
        black = TPUv5eSim(knowledge="black")
        assert white.known_step_widths("dense") == {"tokens": 8, "d_in": 128, "d_out": 128}
        assert gray.known_step_widths("dense") == {"d_in": 128, "d_out": 128}
        assert black.known_step_widths("dense") is None

    def test_white_box_needs_no_sweeps(self):
        W, sw, n = sweeps.discover_step_widths(TPUv5eSim(knowledge="white"), "dense")
        assert n == 0 and not sw and W["d_in"] == 128

    def test_dense_mxu_steps_discovered(self):
        tpu = TPUv5eSim(knowledge="black")
        W, _, _ = sweeps.discover_step_widths(tpu, "dense")
        assert W["d_in"] == 128 and W["d_out"] == 128

    def test_moe_token_step_width(self):
        """tokens step = E*sublane/topk -- only discoverable by sweeps."""
        tpu = TPUv5eSim(knowledge="black", moe_experts=64, moe_topk=8)
        W, _, _ = sweeps.discover_step_widths(tpu, "moe_gemm")
        assert W["tokens"] == 64

    def test_decode_page_quantisation(self):
        tpu = TPUv5eSim()
        base = tpu.defaults("attention_decode")
        t1 = tpu.measure("attention_decode", {**base, "S_kv": 4097})
        t2 = tpu.measure("attention_decode", {**base, "S_kv": 4224})
        assert t1 == t2  # same 128-token page

    def test_roofline_max_rule(self):
        """Single layer sits at max(flops, bytes) + overhead."""
        tpu = TPUv5eSim()
        f, m = tpu._terms("dense", {"tokens": 8, "d_in": 8192, "d_out": 8192})
        assert m > f  # tiny-batch GEMM is memory-bound
        t = tpu.measure("dense", {"tokens": 8, "d_in": 8192, "d_out": 8192})
        assert t == pytest.approx(m + tpu.chip.launch_overhead_s)

    def test_block_overlap_faster_than_sum(self):
        """Fused blocks overlap compute/DMA: t_block < sum of layer times."""
        tpu = TPUv5eSim()
        layers = [("dense", tpu.defaults("dense"))] * 3
        t_block = tpu.measure_block(layers)
        t_sum = sum(tpu.measure(lt, c) for lt, c in layers)
        assert t_block < t_sum

    def test_collective_term_eq9(self):
        tpu = TPUv5eSim()
        layers = [("dense", {"tokens": 64, "d_in": 256, "d_out": 256})]
        slow_coll = tpu.measure_block(layers, collective_bytes=1e9)
        fast_coll = tpu.measure_block(layers, collective_bytes=0.0)
        assert slow_coll > fast_coll  # ICI-bound branch of the max rule

    def test_deterministic_noise(self):
        tpu = TPUv5eSim(noise=0.01)
        cfg = tpu.defaults("dense")
        assert tpu.measure("dense", cfg) == tpu.measure("dense", cfg)


class TestXLACPU:
    def test_measures_positive_and_monotone_ish(self):
        cpu = XLACPUPlatform(repeats=3)
        t_small = cpu.measure("dense", {"tokens": 16, "d_in": 32, "d_out": 32})
        t_big = cpu.measure("dense", {"tokens": 256, "d_in": 768, "d_out": 768})
        assert t_small > 0 and t_big > t_small
