"""Hypothesis property tests for prs / steps / forest.

Kept in their own module (the deterministic tests live in test_prs.py,
test_steps.py, test_forest.py) so that only the property tests skip when
hypothesis is not installed.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # degrade gracefully when missing
from hypothesis import given, settings, strategies as st

from repro.core import prs, steps
from repro.core.forest import RandomForestRegressor

SPACE = prs.ParamSpace(ranges={"C": (1, 56), "K": (1, 56), "W": (3, 256)})
WIDTHS = {"C": 8, "K": 8, "W": 1}


@settings(max_examples=50, deadline=None)
@given(
    c=st.integers(1, 56),
    k=st.integers(1, 56),
    w=st.integers(3, 256),
)
def test_property_pr_mapping(c, k, w):
    cfg = {"C": c, "K": k, "W": w}
    snapped = prs.map_to_pr(cfg, WIDTHS, SPACE)
    # idempotent
    assert prs.map_to_pr(snapped, WIDTHS, SPACE) == snapped
    # next-larger multiple, within one step
    assert snapped["C"] >= min(c, snapped["C"])
    assert snapped["C"] % 8 == 0 and 0 <= snapped["C"] - c < 8 or snapped["C"] == 56
    # linear params untouched
    assert snapped["W"] == w


@settings(max_examples=300, deadline=None)
@given(
    lo=st.integers(1, 64),
    span=st.integers(0, 200),
    w=st.integers(1, 32),
    frac=st.floats(0.0, 1.0),
)
def test_property_map_to_pr_lands_on_pr_grid(lo, span, w, frac):
    """map_to_pr always lands on a pr_values grid point, for every range/width
    combination — including the degenerate hi < w and lo-past-last-multiple
    cases whose only representative is hi."""
    hi = lo + span
    space = prs.ParamSpace(ranges={"p": (lo, hi)})
    v = lo + int(round(frac * span))
    snapped = prs.map_to_pr({"p": v}, {"p": w}, space)["p"]
    assert snapped in set(prs.pr_values(lo, hi, w).tolist())


def _staircase(x, width, step_height=1.0, base=10.0):
    return base + step_height * np.ceil(x / width)


@settings(max_examples=30, deadline=None)
@given(
    width=st.sampled_from([2, 4, 8, 16, 32, 64]),
    base=st.floats(1.0, 1e3),
    height=st.floats(0.5, 10.0),
)
def test_property_recovers_planted_width(width, base, height):
    x = np.arange(1, 7 * width + 1)
    y = _staircase(x, width, step_height=height, base=base)
    assert steps.find_step_width(x, y) == width


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_no_extrapolation(seed):
    """Forests only predict within the training range (paper Sec. 3.3)."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 10, size=(200, 2))
    y = X[:, 0] + X[:, 1]
    f = RandomForestRegressor(n_estimators=8, seed=seed).fit(X, y)
    X_out = rng.uniform(50, 100, size=(50, 2))  # far outside training
    yp = f.predict(X_out)
    assert np.all(yp <= y.max() + 1e-9) and np.all(yp >= y.min() - 1e-9)
