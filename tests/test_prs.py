"""PR sets, sampling, and the Eq. 7/8 PR mapping."""

import numpy as np
import pytest

from repro.accelerators import UltraTrailSim
from repro.core import prs


SPACE = prs.ParamSpace(ranges={"C": (1, 56), "K": (1, 56), "W": (3, 256)})
WIDTHS = {"C": 8, "K": 8, "W": 1}


def test_pr_values():
    assert list(prs.pr_values(1, 56, 8)) == [8, 16, 24, 32, 40, 48, 56]
    assert list(prs.pr_values(3, 10, 1)) == list(range(3, 11))
    assert list(prs.pr_values(1, 5, 8)) == [5]  # range smaller than one step


def test_paper_exact_counts():
    """The paper quotes |full|=95 585 280 and |PR|=1 493 520 for UltraTrail."""
    ut = UltraTrailSim()
    space = ut.param_space("conv1d")
    widths = ut.known_step_widths("conv1d")
    assert space.size() == 95_585_280
    assert prs.count_pr_configs(space, widths) == 1_493_520


def test_map_to_pr_ceil():
    cfg = {"C": 9, "K": 16, "W": 100}
    snapped = prs.map_to_pr(cfg, WIDTHS, SPACE)
    assert snapped == {"C": 16, "K": 16, "W": 100}


def test_map_to_pr_clips_to_space():
    cfg = {"C": 55, "K": 2, "W": 3}
    snapped = prs.map_to_pr(cfg, WIDTHS, SPACE)
    assert snapped["C"] == 56  # ceil(55/8)*8 = 56 within range


def test_samplers_stay_in_space():
    rng = np.random.default_rng(0)
    for c in prs.sample_pr_configs(SPACE, WIDTHS, 100, rng):
        assert c["C"] % 8 == 0 and c["K"] % 8 == 0
        assert 3 <= c["W"] <= 256
    for c in prs.sample_random_configs(SPACE, 100, rng):
        assert 1 <= c["C"] <= 56 and 3 <= c["W"] <= 256


def test_configs_to_matrix_order():
    X = prs.configs_to_matrix([{"C": 1, "K": 2, "W": 3}], ("C", "K", "W"))
    assert X.tolist() == [[1.0, 2.0, 3.0]]


def test_map_to_pr_degenerate_ranges():
    # hi < w: the only representative is hi itself.
    space = prs.ParamSpace(ranges={"p": (1, 5)})
    assert prs.map_to_pr({"p": 3}, {"p": 8}, space)["p"] == 5
    assert list(prs.pr_values(1, 5, 8)) == [5]
    # lo beyond the last in-range multiple of w: again hi.
    space = prs.ParamSpace(ranges={"p": (57, 60)})
    assert prs.map_to_pr({"p": 58}, {"p": 8}, space)["p"] == 60
    assert list(prs.pr_values(57, 60, 8)) == [60]
