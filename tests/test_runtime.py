"""Runtime substrate: optimizer, data pipeline, checkpointing, fault-tolerant trainer."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.distributed import single_device_rules
from repro.models.config import InputShape, reduced
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm, cosine_schedule
from repro.train.trainer import Trainer, TrainerConfig


class TestAdamW:
    def test_minimizes_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        opt = adamw_init(params)
        cfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0, total_steps=100)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(params, grads, opt, cfg)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.3

    def test_clipping(self):
        g = {"a": jnp.array([3.0, 4.0])}  # norm 5
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(5.0)
        assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)

    def test_schedule(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
        assert float(cosine_schedule(cfg, jnp.array(0))) == 0.0
        assert float(cosine_schedule(cfg, jnp.array(10))) == pytest.approx(1.0)
        assert float(cosine_schedule(cfg, jnp.array(100))) == pytest.approx(0.1, rel=1e-3)


class TestData:
    def test_deterministic_and_restart_safe(self):
        cfg = reduced(get_config("qwen2-1.5b"))
        shape = InputShape("t", 16, 4, "train")
        d1 = SyntheticLMData(cfg, shape, seed=3)
        d2 = SyntheticLMData(cfg, shape, seed=3)
        b1, b2 = d1.batch(7), d2.batch(7)
        assert np.array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(d1.batch(8)["tokens"], b1["tokens"])

    def test_tokens_in_vocab(self):
        cfg = reduced(get_config("qwen2-1.5b"))
        b = SyntheticLMData(cfg, InputShape("t", 16, 4, "train")).batch(0)
        assert b["tokens"].min() >= 1 and b["tokens"].max() < cfg.vocab

    def test_prefetch(self):
        cfg = reduced(get_config("qwen2-1.5b"))
        data = SyntheticLMData(cfg, InputShape("t", 16, 2, "train"))
        it = data.prefetch(start_step=5, depth=2)
        step, batch = next(it)
        assert step == 5 and batch["tokens"].shape == (2, 16)


class TestCheckpoint:
    def test_roundtrip_and_keep_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
        for step in (5, 10, 15):
            mgr.save(step, tree)
        assert mgr.all_steps() == [10, 15]  # keep-2 GC
        restored, step = mgr.restore(tree)
        assert step == 15
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))

    def test_atomicity_ignores_tmp(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        os.makedirs(tmp_path / "step_000000099.tmp")  # simulated crash mid-save
        mgr.save(5, {"x": jnp.zeros(2)})
        assert mgr.latest_step() == 5

    def test_restore_missing_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(FileNotFoundError):
            mgr.restore({"x": jnp.zeros(1)})


class TestTrainerFaultTolerance:
    def test_crash_and_resume(self, tmp_path):
        """Injected failure at step 4 -> restart resumes from the checkpoint."""
        cfg = reduced(get_config("qwen2-1.5b"))
        shape = InputShape("t", 16, 4, "train")
        rules = single_device_rules()
        tcfg = TrainerConfig(
            steps=6, checkpoint_every=2, checkpoint_dir=str(tmp_path), keep=2, log_every=100
        )

        class Boom(RuntimeError):
            pass

        def fail_once(step):
            if step == 4 and not os.environ.get("_REPRO_TEST_FAILED"):
                os.environ["_REPRO_TEST_FAILED"] = "1"
                raise Boom("injected node failure")

        t1 = Trainer(cfg, shape, rules, tcfg, failure_hook=fail_once)
        with pytest.raises(Boom):
            t1.run()
        assert CheckpointManager(str(tmp_path)).latest_step() == 4

        t2 = Trainer(cfg, shape, rules, tcfg, failure_hook=fail_once)
        metrics = t2.run()  # resumes from step 4, finishes 6
        os.environ.pop("_REPRO_TEST_FAILED", None)
        assert metrics["step"] == 5
        # resumed run re-trains only steps 4..5
        assert [h["step"] for h in t2.history] == [4, 5]
        assert np.isfinite(metrics["loss"])

    def test_elastic_restore_shapes(self, tmp_path):
        """Restore re-places arrays with the new rules' shardings (1-device here)."""
        from repro.launch.shardings import param_specs, to_shardings
        from repro.models import transformer as T

        cfg = reduced(get_config("internlm2-1.8b"))
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"params": params})
        rules = single_device_rules()
        specs = param_specs(cfg, rules, jax.eval_shape(lambda: params))
        shardings = to_shardings(rules, specs)
        restored, _ = mgr.restore({"params": params}, shardings={"params": shardings})
        leaf = jax.tree.leaves(restored["params"])[0]
        assert leaf.sharding is not None
