"""Oracle serving layer: coalescing, caching, metrics, transport.

The serving contract under test:

* **bitwise parity** — a served answer (coalesced, cached, or over a
  socket) is identical to a direct ``PerfOracle`` call, because forest
  predictions are row-independent and cached values are the exact float64
  bits the forest produced (JSON round-trips doubles exactly);
* **coalescing** — concurrent requests share forest passes (the batch-size
  histogram proves it) without changing any answer;
* **robustness** — malformed requests, unknown ops/platforms and bad
  payloads produce error *responses*, never a dead server;
* **warm restart** — a new server over the same hub reloads persisted
  estimators and answers identically, without retraining.
"""

from __future__ import annotations

import json
import socket
import threading
import time

import numpy as np
import pytest

import repro.runtime.testing  # noqa: F401  (registers "stepped_sim")
from repro.api import Campaign, CampaignSpec, EstimatorHub, PerfOracle
from repro.core.blocks import Block
from repro.serving import (
    AdmissionBatcher,
    DeadlineExceeded,
    MetricsRegistry,
    OracleClient,
    OracleServer,
    OracleSocketServer,
    OverloadError,
    ResultCache,
    ServeSpec,
    ServingError,
    block_payload,
    parse_block,
)

FAST_FOREST = {"n_estimators": 6, "max_depth": 10}
PLATFORM = "stepped_sim"


@pytest.fixture(scope="module")
def oracle() -> PerfOracle:
    spec = CampaignSpec(
        platform=PLATFORM,
        layer_types=("toy",),
        n_samples=80,
        seed=0,
        forest_kwargs=FAST_FOREST,
    )
    return Campaign(spec).run()


def _server(oracle, **spec_kwargs) -> OracleServer:
    spec_kwargs.setdefault("window_s", 0.001)
    return OracleServer(oracles={PLATFORM: oracle}, spec=ServeSpec(**spec_kwargs))


def _configs(n: int, offset: int = 0) -> list[dict]:
    return [
        {"a": (i * 7 + offset) % 64 + 1, "b": (i * 3 + offset) % 32 + 1}
        for i in range(n)
    ]


def _networks() -> list[list[Block]]:
    return [
        [
            Block(kind="k", layers=(("toy", {"a": 4, "b": 2}), ("toy", {"a": 8, "b": 4})), repeat=3),
            Block(kind="k", layers=(("toy", {"a": 16, "b": 8}),), collective_bytes=128.0),
        ],
        [Block(kind="k", layers=(("toy", {"a": 32, "b": 16}),))],
    ]


# --------------------------------------------------------------------- parity
class TestServedParity:
    def test_predict_matches_direct_oracle_bitwise(self, oracle):
        cfgs = _configs(37)
        direct = oracle.predict("toy", cfgs)
        with _server(oracle) as server:
            client = OracleClient(server=server)
            served = client.predict(PLATFORM, "toy", cfgs)
            # and again: the second pass is all cache hits — still identical
            cached = client.predict(PLATFORM, "toy", cfgs)
        assert served == [float(v) for v in direct]
        assert cached == served
        assert server.cache.stats()["hits"] >= len(cfgs)

    def test_predict_networks_matches_direct_oracle_bitwise(self, oracle):
        nets = _networks()
        direct = oracle.predict_networks(nets)
        with _server(oracle) as server:
            client = OracleClient(server=server)
            served = client.predict_networks(PLATFORM, nets)
            again = client.predict_networks(PLATFORM, nets)
        assert served == [float(v) for v in direct]
        assert again == served

    def test_predict_many_slices_match_standalone_predicts(self, oracle):
        items = [("toy", _configs(5)), ("toy", _configs(9, offset=3))]
        merged = oracle.predict_many(items)
        for (lt, cfgs), got in zip(items, merged):
            assert np.array_equal(got, oracle.predict(lt, cfgs))

    def test_socket_round_trip_is_bitwise_identical(self, oracle):
        cfgs = _configs(11)
        nets = _networks()
        with _server(oracle) as server:
            inproc = OracleClient(server=server)
            with OracleSocketServer(server, port=0).start() as sock:
                remote = OracleClient(address=sock.address)
                assert remote.predict(PLATFORM, "toy", cfgs) == inproc.predict(
                    PLATFORM, "toy", cfgs
                )
                assert remote.predict_networks(PLATFORM, nets) == inproc.predict_networks(
                    PLATFORM, nets
                )
                remote.close()

    def test_autotune_rides_network_coalescing_with_direct_parity(self):
        from repro.configs import get_config
        from repro.core.advisor import autotune
        from repro.models.config import SHAPES

        class _Stub:
            def predict_one(self, cfg) -> float:
                return 1e-6 * float(sum(v for v in cfg.values()))

        class _StubMap(dict):
            def __missing__(self, key):
                est = self[key] = _Stub()
                return est

        stub_oracle = PerfOracle(estimators=_StubMap())
        cfg = get_config("qwen2-1.5b")
        shape = SHAPES["train_4k"]
        direct = autotune(stub_oracle, cfg, shape, chips=16)
        with _server(stub_oracle) as server:
            client = OracleClient(server=server)
            served = client.autotune(
                PLATFORM, "qwen2-1.5b",
                shape_name=shape.name, seq_len=shape.seq_len,
                batch=shape.global_batch, kind=shape.kind, chips=16,
            )
        assert len(served) == len(direct)
        for (cand, seconds), row in zip(direct, served):
            assert (cand.dp, cand.tp, cand.microbatches) == (
                row["dp"], row["tp"], row["microbatches"]
            )
            if np.isfinite(seconds):
                assert row["seconds"] == seconds
            else:
                assert row["seconds"] is None


# ------------------------------------------------------------------ batching
class TestAdmissionBatcher:
    def test_concurrent_submits_coalesce_into_one_process_call(self):
        calls: list[int] = []
        release = threading.Event()

        def process(payloads):
            calls.append(len(payloads))
            return [p * 2 for p in payloads]

        with AdmissionBatcher(process, window_s=0.05) as batcher:
            results: dict[int, int] = {}
            barrier = threading.Barrier(8)

            def worker(i):
                barrier.wait()
                results[i] = batcher.submit(i)

            threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            release.set()
        assert results == {i: i * 2 for i in range(8)}
        assert max(calls) > 1  # at least one batch actually coalesced

    def test_per_item_exception_poisons_only_its_waiter(self):
        def process(payloads):
            return [
                ValueError("poisoned") if p == "bad" else p for p in payloads
            ]

        with AdmissionBatcher(process, window_s=0.001) as batcher:
            assert batcher.submit("ok") == "ok"
            with pytest.raises(ValueError, match="poisoned"):
                batcher.submit("bad")
            assert batcher.submit("still ok") == "still ok"

    def test_submit_after_close_raises(self):
        batcher = AdmissionBatcher(lambda ps: ps, window_s=0.001)
        batcher.close()
        with pytest.raises(ServingError):
            batcher.submit(1)


# ----------------------------------------------------------- overload control
class TestOverloadControl:
    def test_queue_overflow_is_an_explicit_answer_never_a_silent_drop(self):
        """Every submit is accounted for: answered with a result or answered
        with OverloadError — admitted + overloaded == issued."""
        entered = threading.Event()
        release = threading.Event()

        def process(payloads):
            entered.set()
            release.wait(timeout=10.0)
            return [p * 2 for p in payloads]

        batcher = AdmissionBatcher(process, window_s=0.001, max_queue=2)
        try:
            results: dict[int, int] = {}
            overloads: list[int] = []

            def plug():
                results[0] = batcher.submit(0)

            plug_thread = threading.Thread(target=plug)
            plug_thread.start()
            assert entered.wait(timeout=10.0)  # dispatcher is busy in process

            def worker(i):
                try:
                    results[i] = batcher.submit(i)
                except OverloadError:
                    overloads.append(i)

            threads = [threading.Thread(target=worker, args=(i,)) for i in (1, 2, 3, 4)]
            for t in threads:
                t.start()
            # the queued (non-overloaded) submits are parked in the queue
            deadline = time.perf_counter() + 10.0
            while len(overloads) < 2 and time.perf_counter() < deadline:
                time.sleep(0.005)
            release.set()
            plug_thread.join(timeout=10.0)
            for t in threads:
                t.join(timeout=10.0)
            assert len(results) + len(overloads) == 5  # nothing vanished
            assert len(overloads) == 2  # queue bound of 2 admitted exactly 2
            assert all(results[i] == i * 2 for i in results)
        finally:
            release.set()
            batcher.close()

    def test_server_marks_overload_responses(self, oracle):
        with _server(oracle) as server:
            def submit_overloaded(payload, deadline_s=None):
                raise OverloadError("queue full")

            server.batcher.submit = submit_overloaded
            response = server.handle(
                {"id": 1, "op": "predict", "platform": PLATFORM,
                 "layer_type": "toy", "configs": _configs(64)}
            )
        assert response["ok"] is False
        assert response["overloaded"] is True
        assert "OverloadError" in response["error"]

    def test_client_sees_overload_as_serving_error(self, oracle):
        with _server(oracle, max_queue=1) as server:
            entered = threading.Event()
            release = threading.Event()
            real_process = server.batcher.process

            def slow_process(payloads):
                entered.set()
                release.wait(timeout=10.0)
                return real_process(payloads)

            server.batcher.process = slow_process
            client = OracleClient(server=server)
            try:
                ok: list[list] = []
                errors: list[Exception] = []

                def worker(offset):
                    try:
                        ok.append(client.predict(PLATFORM, "toy", _configs(4, offset)))
                    except ServingError as exc:
                        errors.append(exc)

                threads = [
                    threading.Thread(target=worker, args=(o,)) for o in range(6)
                ]
                for t in threads:
                    t.start()
                entered.wait(timeout=10.0)
                deadline = time.perf_counter() + 10.0
                while len(ok) + len(errors) < 5 and time.perf_counter() < deadline:
                    time.sleep(0.005)
            finally:
                release.set()
            for t in threads:
                t.join(timeout=10.0)
            assert len(ok) + len(errors) == 6
            assert any("OverloadError" in str(e) for e in errors)


# ------------------------------------------------------------------ deadlines
class TestDeadlines:
    def test_submit_deadline_raises_typed_error(self):
        release = threading.Event()

        def process(payloads):
            release.wait(timeout=10.0)
            return list(payloads)

        batcher = AdmissionBatcher(process, window_s=0.001)
        try:
            t0 = time.perf_counter()
            with pytest.raises(DeadlineExceeded):
                batcher.submit("x", deadline_s=0.05)
            assert time.perf_counter() - t0 < 5.0  # did not wait forever
        finally:
            release.set()
            batcher.close()

    def test_expired_queue_entries_are_answered_not_dropped(self):
        """An entry whose deadline lapses while queued is answered with
        DeadlineExceeded at drain time — it never consumes a batch slot and
        never silently vanishes."""
        entered = threading.Event()
        release = threading.Event()
        processed: list = []

        def process(payloads):
            if not entered.is_set():
                entered.set()
                release.wait(timeout=10.0)
            processed.extend(payloads)
            return list(payloads)

        batcher = AdmissionBatcher(process, window_s=0.001)
        try:
            plug = threading.Thread(target=batcher.submit, args=("plug",))
            plug.start()
            assert entered.wait(timeout=10.0)
            with pytest.raises(DeadlineExceeded):
                batcher.submit("doomed", deadline_s=0.05)  # expires while queued
            release.set()
            plug.join(timeout=10.0)
            assert batcher.submit("after") == "after"
            assert "doomed" not in processed  # expired entry skipped dispatch
        finally:
            release.set()
            batcher.close()

    def test_server_marks_deadline_responses_and_validates_field(self, oracle):
        with _server(oracle) as server:
            def submit_expired(payload, deadline_s=None):
                raise DeadlineExceeded("too slow")

            server.batcher.submit = submit_expired
            response = server.handle(
                {"id": 2, "op": "predict", "platform": PLATFORM,
                 "layer_type": "toy", "configs": _configs(8), "deadline_ms": 50}
            )
            assert response["ok"] is False
            assert response["deadline_exceeded"] is True
            bad = server.handle(
                {"id": 3, "op": "predict", "platform": PLATFORM,
                 "layer_type": "toy", "configs": _configs(2), "deadline_ms": -1}
            )
            assert bad["ok"] is False and "deadline_ms" in bad["error"]

    def test_generous_deadline_answers_normally_and_bitwise(self, oracle):
        cfgs = _configs(9)
        direct = [float(v) for v in oracle.predict("toy", cfgs)]
        with _server(oracle, default_deadline_s=30.0) as server:
            response = server.handle(
                {"id": 4, "op": "predict", "platform": PLATFORM,
                 "layer_type": "toy", "configs": cfgs, "deadline_ms": 30000}
            )
        assert response["ok"] is True
        assert response["result"] == direct


# -------------------------------------------------------------- graceful drain
class TestGracefulDrain:
    def test_drain_answers_inflight_then_rejects_new_requests(self, oracle):
        entered = threading.Event()
        release = threading.Event()
        real_process = None
        with _server(oracle) as server:
            real_process = server.batcher.process

            def slow_process(payloads):
                entered.set()
                release.wait(timeout=10.0)
                return real_process(payloads)

            server.batcher.process = slow_process
            answers: list[dict] = []

            def inflight():
                answers.append(server.handle(
                    {"id": 5, "op": "predict", "platform": PLATFORM,
                     "layer_type": "toy", "configs": _configs(3)}
                ))

            t = threading.Thread(target=inflight)
            t.start()
            assert entered.wait(timeout=10.0)
            # drain times out while the request is stuck in the batcher...
            assert server.drain(timeout_s=0.05) is False
            # ...new work is already rejected with an explicit flag...
            rejected = server.handle({"id": 6, "op": "ping"})
            assert rejected["ok"] is False and rejected["draining"] is True
            # ...and once released, the in-flight waiter is answered.
            release.set()
            t.join(timeout=10.0)
            assert server.drain(timeout_s=10.0) is True
            assert answers and answers[0]["ok"] is True

    def test_socket_close_answers_inflight_before_closing(self, oracle):
        cfgs = _configs(4)
        direct = [float(v) for v in oracle.predict("toy", cfgs)]
        server = _server(oracle)
        entered = threading.Event()
        release = threading.Event()
        real_process = server.batcher.process

        def slow_process(payloads):
            entered.set()
            release.wait(timeout=10.0)
            return real_process(payloads)

        server.batcher.process = slow_process
        sock = OracleSocketServer(server, port=0).start()
        client = OracleClient(address=sock.address)
        results: list = []
        t = threading.Thread(
            target=lambda: results.append(client.predict(PLATFORM, "toy", cfgs))
        )
        t.start()
        assert entered.wait(timeout=10.0)
        release_timer = threading.Timer(0.2, release.set)
        release_timer.start()
        sock.close(drain_s=10.0)  # must wait for the in-flight answer
        t.join(timeout=10.0)
        client.close()
        assert results == [direct]


# ----------------------------------------------------------- client reconnect
class TestClientReconnect:
    def test_client_survives_a_server_restart(self, oracle):
        cfgs = _configs(6)
        direct = [float(v) for v in oracle.predict("toy", cfgs)]
        first = OracleSocketServer(_server(oracle), port=0).start()
        host, port = first.address
        client = OracleClient(address=(host, port))
        assert client.predict(PLATFORM, "toy", cfgs) == direct
        first.close(drain_s=0.0)
        # restart on the same port (allow_reuse_address) with fresh state
        second = OracleSocketServer(_server(oracle), host=host, port=port).start()
        try:
            # the old connection is dead; the client reconnects once and resends
            assert client.predict(PLATFORM, "toy", cfgs) == direct
            assert client.ping() is True
        finally:
            client.close()
            second.close(drain_s=0.0)

    def test_permanent_server_death_is_a_serving_error(self, oracle):
        sock = OracleSocketServer(_server(oracle), port=0).start()
        client = OracleClient(address=sock.address)
        assert client.ping() is True
        sock.close(drain_s=0.0)
        with pytest.raises(ServingError):  # never a raw OSError
            client.ping()
        client.close()

    def test_closed_client_raises_cleanly(self, oracle):
        with _server(oracle) as server:
            with OracleSocketServer(server, port=0).start() as sock:
                client = OracleClient(address=sock.address)
                client.close()
                with pytest.raises(ServingError, match="closed"):
                    client.ping()


# --------------------------------------------------------------------- cache
class TestResultCache:
    def test_lru_eviction_and_hit_accounting(self):
        cache = ResultCache(capacity=3)
        cache.put_many(["a", "b", "c"], [1.0, 2.0, 3.0])
        assert cache.get_many(["a", "b"]) == [1.0, 2.0]  # refreshes a, b
        cache.put_many(["d"], [4.0])  # evicts "c" (least recently used)
        assert cache.get_many(["c"]) == [None]
        assert cache.get_many(["a", "d"]) == [1.0, 4.0]
        stats = cache.stats()
        assert stats["size"] == 3 and stats["evictions"] == 1
        assert stats["hits"] == 4 and stats["misses"] == 1
        assert stats["hit_rate"] == 4 / 5

    def test_none_keys_are_never_stored(self):
        cache = ResultCache(capacity=4)
        cache.put_many([None, "x"], [1.0, 2.0])
        assert len(cache) == 1
        assert cache.get_many([None]) == [None]
        assert cache.stats()["misses"] == 1

    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError):
            ResultCache(capacity=0)

    def test_server_eviction_still_answers_correctly(self, oracle):
        cfgs = _configs(16)
        direct = [float(v) for v in oracle.predict("toy", cfgs)]
        with _server(oracle, cache_capacity=4) as server:
            client = OracleClient(server=server)
            for _ in range(3):  # repeated sweeps churn the tiny cache
                assert client.predict(PLATFORM, "toy", cfgs) == direct
            assert server.cache.stats()["evictions"] > 0


# ------------------------------------------------------------------- metrics
class TestMetrics:
    def test_registry_reports_percentiles_and_batches(self):
        reg = MetricsRegistry(window=16)
        for i in range(10):
            reg.observe("predict", latency_s=0.001 * (i + 1), items=2)
        reg.observe("predict", latency_s=0.5, error=True)
        reg.observe_batch(3)
        reg.observe_batch(5)
        snap = reg.snapshot()
        ep = snap["endpoints"]["predict"]
        assert ep["requests"] == 11 and ep["errors"] == 1 and ep["items"] == 21
        assert ep["p50_ms"] == pytest.approx(5.5)
        assert ep["p99_ms"] <= 10.0  # the error latency was not recorded
        assert snap["batches"] == 2 and snap["mean_batch_size"] == 4.0
        assert snap["batch_size_hist"] == {"4": 1, "8": 1}

    def test_server_stats_endpoint_shape(self, oracle):
        with _server(oracle) as server:
            client = OracleClient(server=server)
            client.predict(PLATFORM, "toy", _configs(4))
            stats = client.stats()
        assert stats["platforms"]["loaded"] == [PLATFORM]
        assert set(stats["result_cache"]) >= {"hits", "misses", "hit_rate", "evictions"}
        ep = stats["metrics"]["endpoints"]["predict"]
        for field in ("requests", "errors", "items", "requests_per_s",
                      "items_per_s", "p50_ms", "p95_ms", "p99_ms"):
            assert field in ep
        assert stats["metrics"]["batches"] >= 1


# --------------------------------------------------------------- concurrency
class TestConcurrentClients:
    def test_stress_deterministic_answers_and_coalescing(self, oracle):
        per_thread = 6
        n_threads = 16
        expected = {}
        for i in range(n_threads):
            cfgs = _configs(per_thread, offset=i)
            expected[i] = [float(v) for v in oracle.predict("toy", cfgs)]
        with _server(oracle, window_s=0.005) as server:
            client = OracleClient(server=server)
            results: dict[int, list] = {}
            errors: list[Exception] = []
            barrier = threading.Barrier(n_threads)

            def worker(i):
                try:
                    barrier.wait()
                    out = []
                    for j in range(per_thread):
                        out.extend(
                            client.predict(PLATFORM, "toy", [_configs(per_thread, offset=i)[j]])
                        )
                    results[i] = out
                except Exception as exc:  # pragma: no cover - failure detail
                    errors.append(exc)

            threads = [threading.Thread(target=worker, args=(i,)) for i in range(n_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            snap = server.metrics.snapshot()
        assert not errors
        assert results == expected
        # concurrency actually coalesced: fewer dispatches than requests
        assert snap["batches"] < n_threads * per_thread
        assert snap["mean_batch_size"] > 1.0

    def test_concurrent_socket_clients(self, oracle):
        cfgs = _configs(5)
        direct = [float(v) for v in oracle.predict("toy", cfgs)]
        with _server(oracle) as server:
            with OracleSocketServer(server, port=0).start() as sock:
                outputs: list[list] = []
                errors: list[Exception] = []

                def worker():
                    try:
                        with OracleClient(address=sock.address) as c:
                            outputs.append(c.predict(PLATFORM, "toy", cfgs))
                    except Exception as exc:  # pragma: no cover
                        errors.append(exc)

                threads = [threading.Thread(target=worker) for _ in range(8)]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
        assert not errors
        assert outputs == [direct] * 8


# ---------------------------------------------------------------- robustness
class TestRobustness:
    def test_malformed_requests_do_not_kill_the_server(self, oracle):
        with _server(oracle) as server:
            with OracleSocketServer(server, port=0).start() as sock:
                raw = socket.create_connection(sock.address)
                rfile = raw.makefile("rb")
                for bad in (
                    b"this is not json\n",
                    b"[1, 2, 3]\n",
                    b'{"op": "no_such_op"}\n',
                    b'{"op": "predict"}\n',
                    b'{"op": "predict", "platform": "nope", "layer_type": "toy", "configs": []}\n',
                    b'{"op": "predict", "platform": "stepped_sim", "layer_type": "nope", "configs": [{"a": 1}]}\n',
                    b'{"op": "predict_networks", "platform": "stepped_sim", "networks": [[42]]}\n',
                ):
                    raw.sendall(bad)
                    response = json.loads(rfile.readline())
                    assert response["ok"] is False and response["error"]
                # the same connection still serves good requests
                raw.sendall(
                    b'{"id": 9, "op": "predict", "platform": "stepped_sim", '
                    b'"layer_type": "toy", "configs": [{"a": 8, "b": 4}]}\n'
                )
                response = json.loads(rfile.readline())
                assert response["ok"] is True and response["id"] == 9
                raw.close()
            snap = server.metrics.snapshot()
            errors = sum(ep["errors"] for ep in snap["endpoints"].values())
            assert errors >= 5  # JSON-level failures never reach an endpoint

    def test_unknown_platform_is_a_serving_error(self, oracle):
        with _server(oracle) as server:
            client = OracleClient(server=server)
            with pytest.raises(ServingError, match="unknown platform"):
                client.predict("nope", "toy", [{"a": 1, "b": 1}])

    def test_block_payload_round_trip(self):
        block = _networks()[0][0]
        assert parse_block(block_payload(block)) == block
        assert parse_block(block) is block
        with pytest.raises(ServingError):
            parse_block(42)


# ------------------------------------------------------------------- restart
class TestWarmRestart:
    def test_new_server_over_same_hub_answers_identically(self, oracle, tmp_path):
        hub = EstimatorHub(str(tmp_path / "hub"))
        oracle.save(hub, PLATFORM)
        cfgs = _configs(9)
        nets = _networks()
        spec = ServeSpec(hub_dir=str(tmp_path / "hub"), window_s=0.001)
        with OracleServer(spec=spec) as first:
            c1 = OracleClient(server=first)
            layers_1 = c1.predict(PLATFORM, "toy", cfgs)
            nets_1 = c1.predict_networks(PLATFORM, nets)
        # "restart": a brand-new server process state over the same directory
        with OracleServer(spec=dataclasses_replace(spec, platforms=(PLATFORM,))) as second:
            assert PLATFORM in second.platforms()["loaded"]  # warm at startup
            c2 = OracleClient(server=second)
            assert c2.predict(PLATFORM, "toy", cfgs) == layers_1
            assert c2.predict_networks(PLATFORM, nets) == nets_1
        assert layers_1 == [float(v) for v in oracle.predict("toy", cfgs)]

    def test_gc_op_compacts_hub_artifacts(self, oracle, tmp_path):
        hub = EstimatorHub(str(tmp_path / "hub"), keep=4)
        for _ in range(4):
            oracle.save(hub, PLATFORM)
        spec = ServeSpec(hub_dir=str(tmp_path / "hub"))
        with OracleServer(spec=spec) as server:
            client = OracleClient(server=server)
            before = client.predict(PLATFORM, "toy", _configs(4))
            out = client.gc()  # the serving hub's default keep is 2
            assert out["steps_removed"] == 2
            # answers unchanged after gc (latest checkpoint untouched)
            assert client.predict(PLATFORM, "toy", _configs(4)) == before
        with OracleServer(spec=spec) as reloaded:
            c2 = OracleClient(server=reloaded)
            assert c2.predict(PLATFORM, "toy", _configs(4)) == before


def dataclasses_replace(spec: ServeSpec, **changes) -> ServeSpec:
    import dataclasses

    return dataclasses.replace(spec, **changes)
