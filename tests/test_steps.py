"""Algorithm 1: step-width detection from sweeps."""

import numpy as np
import pytest

from repro.core import steps


def staircase(x, width, step_height=1.0, base=10.0, noise=0.0, seed=0):
    y = base + step_height * np.ceil(x / width)
    if noise:
        rng = np.random.default_rng(seed)
        y = y * rng.lognormal(0.0, noise, size=y.shape)
    return y


class TestLinearBehavior:
    def test_linear_is_linear(self):
        x = np.arange(1, 200)
        assert steps.test_linear_behavior(x, 3.0 * x + 7)

    def test_staircase_is_not_linear(self):
        x = np.arange(1, 200)
        assert not steps.test_linear_behavior(x, staircase(x, 16))

    def test_constant_is_linear(self):
        x = np.arange(1, 50)
        assert steps.test_linear_behavior(x, np.full_like(x, 5.0, dtype=float))

    def test_noisy_linear(self):
        x = np.arange(1, 300)
        y = (2.0 * x + 5) * np.random.default_rng(0).lognormal(0, 0.002, size=x.shape)
        assert steps.test_linear_behavior(x, y)


class TestFindStepWidth:
    @pytest.mark.parametrize("width", [2, 8, 16, 64, 128])
    def test_exact_staircase(self, width):
        x = np.arange(1, max(6 * width, 64))
        assert steps.find_step_width(x, staircase(x, width)) == width

    @pytest.mark.parametrize("width", [8, 32])
    def test_noisy_staircase(self, width):
        x = np.arange(1, 8 * width)
        y = staircase(x, width, noise=0.003)
        assert steps.find_step_width(x, y) == width

    def test_linear_returns_one(self):
        x = np.arange(1, 100)
        assert steps.find_step_width(x, 0.5 * x + 1) == 1

    def test_sloped_staircase(self):
        # step + linear component (common: tiles + streaming term)
        x = np.arange(1, 200)
        y = staircase(x, 16) + 0.002 * x
        assert steps.find_step_width(x, y) == 16

    def test_offset_sweep_window(self):
        # sweep window not starting at 1 (anchored mid-range)
        x = np.arange(1000, 1500)
        assert steps.find_step_width(x, staircase(x, 128)) == 128


def test_determine_step_widths_dict():
    x = np.arange(1, 128)
    sweeps = {
        "a": (x, staircase(x, 8)),
        "b": (x, 2.0 * x + 3),
    }
    assert steps.determine_step_widths(sweeps) == {"a": 8, "b": 1}


def test_detect_pr_points():
    x = np.arange(1, 33)
    prs = steps.detect_pr_points(x, staircase(x, 8), 8)
    assert list(prs) == [8, 16, 24, 32]


class TestVectorizedStaircaseFit:
    """The bincount-vectorized staircase fit matches a per-step reference loop."""

    @staticmethod
    def _reference_rmse(x, y, width):
        g = np.ceil(x / max(1, width)).astype(np.int64)
        y_hat = np.empty_like(y)
        for gv in np.unique(g):
            m = g == gv
            y_hat[m] = float(np.mean(y[m]))
        return float(np.sqrt(np.mean((y - y_hat) ** 2)))

    @pytest.mark.parametrize("width", [2, 5, 8, 17, 200])
    def test_matches_reference_loop(self, width):
        x = np.arange(1, 97).astype(np.float64)
        y = staircase(x, 8, noise=0.05)
        ref = self._reference_rmse(x, y, width)
        vec = steps._staircase_fit_rmse(x, y, width)
        assert vec == pytest.approx(ref, rel=1e-12, abs=1e-15)

    def test_multi_equals_per_width_calls(self):
        x = np.arange(1, 129).astype(np.float64)
        y = staircase(x, 16, noise=0.02, seed=3)
        widths = [2, 3, 7, 15, 16, 17, 64]
        multi = steps._staircase_fit_rmse_multi(x, y, widths)
        for w, r in zip(widths, multi):
            assert r == pytest.approx(self._reference_rmse(x, y, w), rel=1e-12)

    def test_offset_window_and_unsorted_x(self):
        # windows anchored mid-range, plus a shuffled copy (the vectorized fit
        # sorts internally; grouping must not depend on input order)
        x = np.arange(1000, 1128).astype(np.float64)
        y = staircase(x, 32, noise=0.01, seed=1)
        ref = self._reference_rmse(x, y, 32)
        assert steps._staircase_fit_rmse(x, y, 32) == pytest.approx(ref, rel=1e-12)
        order = np.random.default_rng(0).permutation(x.size)
        assert steps._staircase_fit_rmse(x[order], y[order], 32) == pytest.approx(
            ref, rel=1e-12
        )
