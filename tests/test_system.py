"""End-to-end behaviour of the paper's system (Fig. 1 pipeline).

sweeps -> Algorithm 1 -> PR set -> PR benchmarking -> Random Forest ->
PR mapping -> single-layer estimates -> building blocks -> whole network.
"""

import numpy as np

from repro.accelerators import TPUv5eSim, UltraTrailSim
from repro.core import prs
from repro.core.blocks import NetworkEstimator, fit_fusing_model
from repro.core.estimator import build_estimator
from repro.core.network import decompose, simulate_network
from repro.configs import get_config
from repro.models.config import SHAPES


def test_full_pipeline_ultratrail():
    """White-box path: documented widths -> PR sampling -> accurate estimates."""
    ut = UltraTrailSim()
    est = build_estimator(ut, "conv1d", 1200, sampling="pr", seed=0)
    # TC-ResNet8-style layers (the paper's UltraTrail test set)
    layers = [
        {"C": 40, "C_w": 101, "K": 16, "F": 3, "s": 1, "pad": 1},
        {"C": 16, "C_w": 101, "K": 24, "F": 9, "s": 2, "pad": 4},
        {"C": 48, "C_w": 13, "K": 48, "F": 9, "s": 1, "pad": 4},
    ]
    m = est.evaluate(ut, layers)
    assert m["mape"] < 10.0


def test_full_pipeline_blackbox_to_whole_network():
    """Black-box path on the TPU sim, through to a whole-model estimate."""
    tpu = TPUv5eSim(knowledge="black", noise=0.001)
    layer_types = ("dense", "attention_prefill", "ssd_scan", "embed")
    ests = {lt: build_estimator(tpu, lt, 500, sampling="pr", seed=1) for lt in layer_types}
    # discovered widths include the MXU quantisation
    assert ests["dense"].widths["d_in"] == 128

    net = NetworkEstimator(estimators=ests)
    cfg = get_config("mamba2-780m")
    blocks = decompose(cfg, SHAPES["train_4k"], dp=16, tp=16)
    t_est = net.predict_network(blocks)
    t_sim = simulate_network(tpu, blocks)
    assert t_est > 0 and t_sim > 0
    # whole-model estimate within 2x of the simulated ground truth even
    # without fusing-factor correction (tightened by the benchmarks)
    assert 0.5 < t_est / t_sim < 2.0


def test_pr_sampling_needs_fewer_samples_than_random():
    """The paper's headline claim, as a regression test."""
    ut = UltraTrailSim()
    space = ut.param_space("conv1d")
    rng = np.random.default_rng(7)
    test = prs.sample_random_configs(space, 50, rng)
    pr_small = build_estimator(ut, "conv1d", 600, sampling="pr", seed=2)
    rand_big = build_estimator(ut, "conv1d", 1200, sampling="random", seed=2)
    assert pr_small.evaluate(ut, test)["mape"] < rand_big.evaluate(ut, test)["mape"]
